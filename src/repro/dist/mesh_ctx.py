"""Session-wide mesh context.

A single contextvar holds the live `jax.sharding.Mesh`; model code asks
`current_mesh()` at trace time and lowers to the matching collectives /
sharding constraints. Keeping it out of function signatures lets the same
model code serve single-device tests, GSPMD, and explicit shard_map paths.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "current_mesh", "data_axes_of", "axis_size",
           "shard_hint", "shard_tp_ctx", "shard_tp"]

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)

# Set (> 0) while tracing the body of a TP shard_map: model code and the
# kernel dispatcher see per-shard local shapes there, so the Pallas routes
# re-engage even though `current_mesh()` is still live (DESIGN.md §14).
_SHARD_TP: contextvars.ContextVar[int] = contextvars.ContextVar(
    "repro_shard_tp", default=0)


@contextlib.contextmanager
def shard_tp_ctx(tp: int):
    """Mark the dynamic extent as the inside of a shard_map body whose
    model-axis size is ``tp``. Entered at trace time by the TP serving
    wrapper (serve/engine.py) and the TP parity tests; everything that
    keys kernel selection off the mesh (`dispatch.pallas_route_active`,
    the models' TP branches) consults `shard_tp()` to distinguish
    "global GSPMD graph under a mesh" from "per-shard body"."""
    token = _SHARD_TP.set(int(tp))
    try:
        yield int(tp)
    finally:
        _SHARD_TP.reset(token)


def shard_tp() -> int:
    """Model-axis size of the enclosing shard_map body (0 outside one)."""
    return _SHARD_TP.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Make `mesh` the session mesh for the dynamic extent of the block."""
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def data_axes_of(mesh) -> Tuple[str, ...]:
    """Batch-parallel axes, in mesh order ("pod" before "data")."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(name: str) -> int:
    """Size of a mesh axis under the current mesh (1 when absent)."""
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def shard_hint(x: jax.Array, *entries) -> jax.Array:
    """Divisibility-safe `with_sharding_constraint`.

    One entry per leading dim of ``x`` (missing entries = None): an axis
    name, a tuple of axis names, or None. Axes absent from the live mesh
    are dropped; a dim that doesn't divide the requested axis product falls
    back to replication instead of erroring. No-op without a mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, e in zip(x.shape, entries + (None,) * (x.ndim - len(entries))):
        axes = tuple(a for a in ((e,) if isinstance(e, str) else (e or ()))
                     if a in mesh.axis_names)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if axes and n > 1 and dim % n == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
