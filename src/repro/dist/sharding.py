"""PartitionSpec inference for param / optimizer / cache / batch trees.

Rules (DESIGN.md §6):
  * Megatron TP over the "model" axis — column-parallel up-projections
    (q/k/v_proj, wi, wg: last dim), row-parallel down-projections
    (o_proj, wo: second-to-last dim), vocab-parallel embedding rows and
    LM-head columns, expert-parallel MoE stacks (the E dim).
  * ZeRO/FSDP over the batch axes ("pod", "data") — every leaf at or above
    `FSDP_MIN_SHARD_ELEMS` additionally shards one free dim; small leaves
    (norm scales, biases) stay replicated, keeping their collectives off
    the critical path.
  * Every rule is divisibility-guarded: a dim that doesn't divide the axis
    product falls back to replication, never errors (the tests assert this
    invariant over every assigned architecture × production mesh).
  * `cfg.parallel == "dp"`: the model axis carries no TP and instead joins
    ZeRO, so parameters shard over data×model.

DBB-packed leaves (`core.dbb.DbbWeight`) inherit their parent's rule: for a
logical [K, N] weight, `values`/`indices`/`bitmask` keep N last and the
compressed K second-to-last, so column rules shard their last dim and row
rules their second-to-last; per-channel `scale` follows N.

Specs are pure data — only `mesh.shape` (axis→size mapping) and
`mesh.axis_names` are consulted, so spec-level tests run with fake meshes
and zero devices.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.dist.mesh_ctx import data_axes_of

__all__ = [
    "FSDP_MIN_SHARD_ELEMS", "param_specs", "opt_state_specs_like",
    "cache_specs", "serve_cache_specs", "batch_specs", "zero_spec",
    "named_sharding_tree", "tp_spec_violations",
]

# leaves below this size stay replicated under ZeRO/FSDP (norm scales,
# biases, small stacks — their all-gathers would cost more than the
# memory saved). 8M elems ≈ 32 MB f32.
FSDP_MIN_SHARD_ELEMS = 1 << 23

_COLUMN = {"q_proj", "k_proj", "v_proj", "wi", "wg"}
_ROW = {"o_proj", "wo"}
_PACKED_FIELDS = {"values", "indices", "bitmask", "scale"}


def _names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "name"):
            out.append(str(e.name))
        elif hasattr(e, "idx"):
            out.append(str(e.idx))
    return tuple(out)


def _axprod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _batch_axes(mesh, batch: int):
    """Longest prefix of the batch axes whose product divides `batch`
    (None when even the first axis doesn't divide)."""
    daxes = data_axes_of(mesh)
    for k in range(len(daxes), 0, -1):
        if batch % _axprod(mesh, daxes[:k]) == 0:
            return daxes[:k] if k > 1 else daxes[0]
    return None


def zero_spec(spec: P, shape: Tuple[int, ...], mesh,
              min_elems: Optional[int] = FSDP_MIN_SHARD_ELEMS,
              axes: Optional[Tuple[str, ...]] = None) -> P:
    """Add ZeRO/FSDP batch-axis sharding to one leaf's spec.

    Leaves smaller than `min_elems` (or min_elems=None) are untouched.
    Scans free (None) dims from the last backwards and assigns the longest
    suffix of `axes` (default: the mesh's batch axes) whose product divides
    that dim — suffix-first so a partial fit still sheds the "data" axis.
    """
    if min_elems is None:
        return spec
    size = 1
    for s in shape:
        size *= s
    if size < min_elems:
        return spec
    entries = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
    used = set()
    for e in entries:
        for a in (e,) if isinstance(e, str) else (e or ()):
            used.add(a)
    cand = tuple(a for a in (axes if axes is not None else data_axes_of(mesh))
                 if a in mesh.axis_names and a not in used)
    if not cand:
        return spec
    for dim in reversed(range(len(shape))):
        if entries[dim] is not None:
            continue
        for k in range(len(cand)):
            sub = cand[k:]
            if shape[dim] % _axprod(mesh, sub) == 0 and _axprod(mesh, sub) > 1:
                entries[dim] = sub if len(sub) > 1 else sub[0]
                return P(*entries)
    return spec


def param_specs(params: Any, mesh, cfg: ModelConfig,
                fsdp_min_shard_elems: Optional[int] = FSDP_MIN_SHARD_ELEMS
                ) -> Any:
    """PartitionSpec tree mirroring `params` (arrays/SDS → P leaves)."""
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    tp_on = tp > 1 and cfg.parallel != "dp" and cfg.family != "cnn"
    zero_axes = data_axes_of(mesh)
    if cfg.parallel == "dp" and "model" in mesh.axis_names:
        zero_axes = zero_axes + ("model",)

    def leaf_spec(path, leaf):
        if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) == 0:
            return P()
        names = _names(path)
        nameset = set(names)
        field = names[-1] if names else ""
        nd = leaf.ndim
        spec = [None] * nd

        if tp_on:
            if "experts" in nameset and nd >= 3:
                if leaf.shape[-3] % tp == 0:
                    spec[-3] = "model"
            elif "embed" in nameset and field == "table":
                if leaf.shape[0] % tp == 0:
                    spec[0] = "model"          # vocab-parallel rows
            elif "lm_head" in nameset:
                if field in {"w"} | _PACKED_FIELDS and \
                        leaf.shape[-1] % tp == 0:
                    spec[-1] = "model"         # vocab-parallel columns
            elif nameset & _COLUMN:
                if field in {"w", "b"} | _PACKED_FIELDS and \
                        leaf.shape[-1] % tp == 0:
                    spec[-1] = "model"
            elif nameset & _ROW:
                if field == "w" and nd >= 2 and leaf.shape[-2] % tp == 0:
                    spec[-2] = "model"
                elif field in ("values", "indices", "bitmask") and nd >= 2:
                    # packed planes shard K in whole DBB blocks: bitmask
                    # rows are blocks, values/indices rows are block-major
                    # slots (nnz per block) — a clean split needs the
                    # shard boundary to land between blocks, never inside
                    # one (the kernels index block-locally per shard)
                    unit = tp if field == "bitmask" else cfg.dbb.nnz * tp
                    if leaf.shape[-2] % unit == 0:
                        spec[-2] = "model"
        return zero_spec(P(*spec), leaf.shape, mesh,
                         min_elems=fsdp_min_shard_elems, axes=zero_axes)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def tp_spec_violations(params: Any, pspecs: Any) -> list:
    """TP-eligible weight leaves whose inferred spec did NOT take the model
    axis (the divisibility fallback replicated them), as path strings.

    The serving shard_map wrap (DESIGN.md §14) requires every
    column/row/vocab-parallel weight to *actually* shard: its boundary
    collectives assume the per-shard GEMM outputs are partial sums, so a
    silently-replicated row weight would be summed tp× — the wrap must
    stay off instead. A row-parallel bias is reported too (it would be
    applied per shard and multiplied by the reduce); no assigned arch
    carries one, this guards refactors."""
    flat_s = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))[0]
    specs_by_path = {_names(path): s for path, s in flat_s}

    def has_model(spec: P) -> bool:
        for e in tuple(spec):
            axes = (e,) if isinstance(e, str) else tuple(e or ())
            if "model" in axes:
                return True
        return False

    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if not hasattr(leaf, "shape") or getattr(leaf, "ndim", 0) == 0:
            continue
        names = _names(path)
        nameset = set(names)
        field = names[-1] if names else ""
        if nameset & _ROW:
            if field == "b":
                out.append("/".join(names) + " (row-parallel bias)")
                continue
            eligible = field in ("w", "values", "indices", "bitmask")
        elif nameset & _COLUMN:
            eligible = field in {"w", "b"} | _PACKED_FIELDS
        elif "embed" in nameset:
            eligible = field == "table"
        elif "lm_head" in nameset:
            eligible = field in {"w"} | _PACKED_FIELDS
        else:
            eligible = False
        if eligible and not has_model(specs_by_path.get(names, P())):
            out.append("/".join(names))
    return out


def _pad_spec(spec: P, nd: int) -> Tuple:
    t = tuple(spec)
    return t + (None,) * (nd - len(t))


def opt_state_specs_like(opt_state: Any, params: Any, pspecs: Any,
                         mesh) -> Any:
    """Specs for an optimizer-state tree derived from the param specs.

    Same-shape moments (adamw m/v, sgd mom, error-feedback) copy the param
    spec. Adafactor factored stats follow the param's surviving axes:
    ``vr`` (shape[:-1]) keeps the leading entries, ``vc``
    (shape[:-2] + shape[-1:]) keeps leading + last. Scalars replicate.
    """
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))[0]
    by_path: Dict[str, Tuple[Any, P]] = {}
    specs_by_path = {_names(path): s for path, s in flat_s}
    for path, leaf in flat_p:
        by_path[_names(path)] = (leaf, specs_by_path.get(_names(path), P()))

    def _is_factored(x):
        return isinstance(x, dict) and ("vr" in x or "v" in x) and all(
            hasattr(v, "shape") for v in x.values())

    def sub_specs(key: str, subtree: Any) -> Any:
        def visit(path, leaf):
            pnames = _names(path)
            hit = by_path.get(pnames)
            if _is_factored(leaf):
                if hit is None:
                    return {k: P() for k in leaf}
                p_leaf, spec = hit
                full = _pad_spec(spec, p_leaf.ndim)
                out = {}
                if "vr" in leaf:
                    out["vr"] = P(*full[:-1])
                if "vc" in leaf:
                    out["vc"] = P(*(full[:-2] + full[-1:]))
                if "v" in leaf:
                    out["v"] = P(*full)
                return out
            if not hasattr(leaf, "shape") or leaf.ndim == 0:
                return P()
            if hit is not None and hit[0].shape == leaf.shape:
                return hit[1]
            return P()

        return jax.tree_util.tree_map_with_path(
            visit, subtree, is_leaf=lambda x: _is_factored(x))

    return {k: sub_specs(k, v) for k, v in opt_state.items()}


def cache_specs(cfg: ModelConfig, mesh, batch: int, seq: int) -> Dict:
    """Specs for the decode cache tree of `cfg` (same keys as init_cache):
    the batch dim shards over the batch axes, everything else replicates."""
    from repro.models import registry             # lazy: avoid import cycle
    ba = _batch_axes(mesh, batch)
    sds = jax.eval_shape(lambda: registry.init_cache(cfg, batch, seq))

    def visit(path, leaf):
        names = _names(path)
        if names and names[-1] == "length":
            return P(ba)
        # stacked [L, B, ...] state: batch at dim 1
        return P(None, ba, *([None] * (leaf.ndim - 2)))

    return jax.tree_util.tree_map_with_path(visit, sds)


def serve_cache_specs(cache: Any, mesh) -> Any:
    """Specs for a serving KV-cache tree under the TP shard_map wrapper
    (DESIGN.md §14): KV heads shard over "model" — dim 3 of both the
    contiguous ``k/v [L, B, S, Hkv, D]`` and the paged ``k_pages/v_pages
    [L, P, page, Hkv, D]`` layouts — so each shard holds only its own
    heads' cache and the paged block tables stay per-shard-valid
    (replicated tables index shard-local pools of local heads).
    Bookkeeping (length/start/block_table/write cursors) replicates.
    Accepts arrays or ShapeDtypeStructs; pure data like `cache_specs`."""
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def visit(path, leaf):
        names = _names(path)
        field = names[-1] if names else ""
        if (field in ("k", "v", "k_pages", "v_pages") and tp > 1
                and getattr(leaf, "ndim", 0) >= 4
                and leaf.shape[3] % tp == 0):
            return P(None, None, None, "model", *([None] * (leaf.ndim - 4)))
        return P(*([None] * getattr(leaf, "ndim", 0)))

    return jax.tree_util.tree_map_with_path(visit, cache)


def batch_specs(cfg: ModelConfig, mesh, global_batch: int, seq: int) -> Dict:
    """Specs for every step-input key (callers .get() what they need);
    batch dim over the batch axes, sequence/feature dims replicated."""
    ba = _batch_axes(mesh, global_batch)
    return {
        "tokens": P(ba, None),
        "labels": P(ba, None),
        "loss_mask": P(ba, None),
        "embeds": P(ba, None, None),
        "prefix_embeds": P(ba, None, None),
        "images": P(ba, None, None, None),
    }


def named_sharding_tree(spec_tree: Any, mesh) -> Any:
    """P tree → NamedSharding tree (leaves that aren't P pass through)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda x: isinstance(x, P))
