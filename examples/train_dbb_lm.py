"""End-to-end driver (brief deliverable b): train a ~100M-param dense LM
with DBB-sparse projections for a few hundred steps on CPU, with
checkpointing mid-run, a simulated preemption + resume, and a final
eval — the full production train path at laptop scale.

Run:  PYTHONPATH=src python examples/train_dbb_lm.py [--steps 200]
(~100M params; a few hundred CPU steps takes a while — use --steps 60
for a quick pass.)
"""
import argparse
import os
import shutil
import tempfile

import jax

from repro.config import (DbbConfig, ModelConfig, RunConfig, ShapeSpec,
                          TrainConfig)
from repro.launch.train import train_loop
from repro.train import checkpoint as ckpt

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--seq-len", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

# ~100M params: 12L, d=512, ff=2048, 32k vocab (olmo-style family)
cfg = ModelConfig(
    name="lm100m", family="dense_lm", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32768,
    norm="rmsnorm", act="silu", mlp_gated=True, dtype="float32",
    remat="none",
    dbb=DbbConfig(enabled=True, block=8, nnz=4),
)
print(f"params ≈ {cfg.param_count() / 1e6:.1f}M")

ckdir = os.path.join(tempfile.gettempdir(), "repro_lm100m_ck")
shutil.rmtree(ckdir, ignore_errors=True)

half = args.steps // 2
shape = ShapeSpec("train", args.seq_len, args.batch, "train")


def rc(steps):
    return RunConfig(model=cfg, train=TrainConfig(
        steps=steps, learning_rate=6e-4, warmup_steps=20,
        microbatches=2, grad_compress="bf16",
        checkpoint_dir=ckdir, checkpoint_every=max(half // 2, 10),
        log_every=10, dbb_prune_start=args.steps // 4,
        dbb_prune_ramp=args.steps // 4))


print(f"\n== phase 1: train to step {half} (simulated preemption) ==")
state, hist1 = train_loop(rc(half), shape)

print("\n== phase 2: resume from latest checkpoint, finish run ==")
assert ckpt.latest_step(ckdir) is not None
state, hist2 = train_loop(rc(args.steps), shape)

first, last = hist1[0]["loss"], hist2[-1]["loss"]
print(f"\nloss: {first:.3f} -> {last:.3f} over {args.steps} steps "
      f"(resumed at {ckpt.latest_step(ckdir)})")
assert last < first, "training diverged?"
print("done.")
