"""Expert-parallel MoE on a virtual 8-device mesh: train an arctic-family
smoke config with experts sharded over the model axis, and verify the EP
path agrees with the single-device dense-dispatch path.

This example sets XLA_FLAGS before importing jax — run it as a script,
not inside a session that already initialized jax.

Run:  PYTHONPATH=src python examples/moe_expert_parallel.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402

from repro.config import RunConfig, ShapeSpec, TrainConfig  # noqa: E402
from repro.configs import get_config           # noqa: E402
from repro.dist import sharding as shd         # noqa: E402
from repro.dist.mesh_ctx import use_mesh       # noqa: E402
from repro.launch.mesh import make_smoke_mesh  # noqa: E402
from repro.models.moe import moe_apply, moe_init  # noqa: E402
from repro.launch.train import train_loop      # noqa: E402

cfg = get_config("arctic-480b", smoke=True)
mesh = make_smoke_mesh(data=2, model=4)
print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} virtual devices")

# --- EP vs local dispatch parity -------------------------------------------
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
hi_cap = cfg.moe.__class__(num_experts=8, top_k=2, capacity_factor=8.0,
                           dense_residual_ff=128)
y_local, _ = moe_apply(p, cfg.replace(moe=hi_cap.__class__(
    **{**hi_cap.__dict__, "impl": "local"})), x)
with use_mesh(mesh):
    y_ep, _ = jax.jit(lambda pp, xx: moe_apply(pp, cfg.replace(
        moe=hi_cap.__class__(**{**hi_cap.__dict__, "impl": "ep"})), xx))(p, x)
err = float(jnp.abs(y_local - y_ep).max())
print(f"EP vs local dispatch max |diff| = {err:.2e}")
assert err < 1e-3

# --- short sharded training run --------------------------------------------
rc = RunConfig(model=cfg, train=TrainConfig(steps=20, learning_rate=1e-3,
                                            log_every=5))
state, hist = train_loop(rc, ShapeSpec("t", 32, 8, "train"), mesh=mesh)
print(f"sharded MoE train loss {hist[0]['loss']:.3f} -> "
      f"{hist[-1]['loss']:.3f}")
print("done.")
