"""Quickstart: the paper's pipeline end-to-end in one minute on CPU.

  1. build a DBB-sparse weight and run the two Pallas GEMMs (STA dense /
     STA-DBB compressed) against their oracles;
  2. run a conv layer through the *implicit-GEMM* kernel — the im2col
     patch matrix is gathered in-kernel in VMEM, never materialized in
     HBM (DESIGN.md §8) — and check it against the explicit lowering;
  3. train the paper's 5-layer ConvNet analogue with annealed DBB pruning;
  4. pack the trained weights to the DBB serving format (the STA-DBB
     memory layout) and report the footprint saving.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DbbConfig, RunConfig, ShapeSpec, TrainConfig
from repro.configs import get_config
from repro.core.dbb import dbb_project, pack_dbb
from repro.core.dbb_linear import pack_tree, tree_footprint_bytes
from repro.core.sparsity import apply_dbb_to_tree
from repro.kernels.dbb_gemm.ops import dbb_gemm_packed
from repro.kernels.sta_gemm.ops import sta_gemm
from repro.launch.train import train_loop

print("== 1. kernels ==")
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (256, 512), jnp.float32)
w = jax.random.normal(jax.random.fold_in(key, 1), (512, 256), jnp.float32)

y_dense = sta_gemm(x, w)                       # STA tensor-PE tiling
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-4)
print("sta_gemm matches XLA matmul")

p = pack_dbb(w, block=8, nnz=4)                # 1x8 DBB, NNZ<=4 (50%)
y_sparse = dbb_gemm_packed(x, p)               # on-chip decompression
np.testing.assert_allclose(np.asarray(y_sparse),
                           np.asarray(x @ dbb_project(w, 8, 4)),
                           rtol=1e-4, atol=1e-4)
print("dbb_gemm matches project-then-matmul oracle")

print("\n== 2. implicit-GEMM conv (fused im2col in-kernel) ==")
from repro.kernels.conv_gemm.ops import conv_gemm, conv_gemm_packed
from repro.kernels.conv_gemm.ref import im2col

xc = jax.random.normal(jax.random.fold_in(key, 2), (2, 16, 16, 8))
wc = jax.random.normal(jax.random.fold_in(key, 3), (3 * 3 * 8, 32)) * 0.1
y_conv = conv_gemm(xc, wc, kh=3, kw=3)          # patch gather in VMEM
cols = im2col(xc, 3, 3)                          # the tensor the kernel avoids
y_ref = (cols.reshape(-1, 72) @ wc).reshape(2, 16, 16, 32)
np.testing.assert_allclose(np.asarray(y_conv), np.asarray(y_ref),
                           rtol=1e-4, atol=1e-4)
pc = pack_dbb(wc, block=8, nnz=4)
y_conv_dbb = conv_gemm_packed(xc, pc, kh=3, kw=3)   # compressed weights too
np.testing.assert_allclose(
    np.asarray(y_conv_dbb),
    np.asarray((cols.reshape(-1, 72) @ dbb_project(wc, 8, 4))
               .reshape(2, 16, 16, 32)), rtol=1e-4, atol=1e-4)
print(f"implicit-GEMM conv matches im2col+GEMM; skipped materializing "
      f"{cols.size * 4} B of patches ({cols.size // xc.size}x the input)")

print("\n== 3. DBB-sparse training (paper §V-A) ==")
cfg = get_config("convnet-dbb", smoke=True)
rc = RunConfig(model=cfg, train=TrainConfig(
    steps=40, learning_rate=3e-3, log_every=10,
    dbb_prune_start=10, dbb_prune_ramp=15))
state, hist = train_loop(rc, ShapeSpec("t", 16, 32, "train"))
print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}, "
      f"final NNZ bound {hist[-1]['nnz']}/8")

print("\n== 4. pack to serving format ==")
dense_bytes = tree_footprint_bytes(state.params)
proj = apply_dbb_to_tree(state.params, cfg.dbb, straight_through=False)
packed = pack_tree(proj, cfg.dbb)
packed_bytes = tree_footprint_bytes(packed)
print(f"weight footprint {dense_bytes} -> {packed_bytes} bytes "
      f"({100 * packed_bytes / dense_bytes:.1f}% of dense)")
print("done.")
