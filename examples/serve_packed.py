"""Batched serving with DBB-packed weights (the paper's deployment mode):
init an olmo-family smoke model, prune+pack its weights to the STA-DBB
memory format, and serve batched greedy generations — verifying packed
and dense serving agree token-for-token and reporting the footprint win.

Run:  PYTHONPATH=src python examples/serve_packed.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.dbb_linear import pack_tree, tree_footprint_bytes
from repro.core.sparsity import apply_dbb_to_tree
from repro.models import registry
from repro.serve.engine import ServeEngine

cfg = get_config("olmo-1b", smoke=True)
params = registry.init_params(jax.random.PRNGKey(0), cfg)

# amplitude-prune to the DBB constraint, then pack (values + bitmask)
proj = apply_dbb_to_tree(params, cfg.dbb, straight_through=False)
packed = pack_tree(proj, cfg.dbb)
d_bytes, p_bytes = tree_footprint_bytes(proj), tree_footprint_bytes(packed)
print(f"weight footprint: {d_bytes / 1e6:.2f} MB dense -> "
      f"{p_bytes / 1e6:.2f} MB packed ({100 * p_bytes / d_bytes:.1f}%)")

rng = np.random.default_rng(0)
prompts = [list(rng.integers(2, cfg.vocab_size, size=n))
           for n in (5, 9, 3)]

eng_dense = ServeEngine(cfg, proj, max_batch=4)
eng_packed = ServeEngine(cfg, packed, max_batch=4)

out_d = eng_dense.generate(prompts, max_new_tokens=8)
out_p = eng_packed.generate(prompts, max_new_tokens=8)
for i, (a, b) in enumerate(zip(out_d, out_p)):
    status = "==" if a == b else "!="
    print(f"req{i}: dense {a} {status} packed {b}")
assert out_d == out_p, "packed serving must match projected-dense serving"
print("packed serving is exact. done.")
