"""Splice generated tables into EXPERIMENTS.md at the GENERATED markers.

    PYTHONPATH=src python scripts/update_experiments.py
"""
import io
import os
import re
import sys
from contextlib import redirect_stdout

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import gen_tables  # noqa: E402  (same directory)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def capture(fn, *a, **kw):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a, **kw)
    return buf.getvalue().strip()


def main():
    cells = gen_tables.load(gen_tables.ART)
    base = (gen_tables.load(gen_tables.BASE)
            if os.path.isdir(gen_tables.BASE) else {})
    sections = {
        "DRYRUN": capture(gen_tables.dryrun_table, cells),
        "ROOFLINE": capture(gen_tables.roofline_table, cells, "pod"),
        "PACKED": capture(gen_tables.packed_table, cells, "pod"),
        "DELTA": (capture(gen_tables.delta_table, cells, base, "pod")
                  if base else ""),
    }
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    for key, content in sections.items():
        marker = f"<!-- GENERATED:{key} -->"
        block = f"{marker}\n{content}\n<!-- /GENERATED:{key} -->"
        pat = re.compile(
            re.escape(marker) + r"(?:.*?<!-- /GENERATED:" + key + r" -->)?",
            re.S)
        text = pat.sub(lambda _: block, text, count=1)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(__file__))
    main()
