"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
artifacts/dryrun/*.json (run after `python -m repro.launch.dryrun`).

    PYTHONPATH=src python scripts/gen_tables.py [--mesh pod]
"""
import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
BASE = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                    "dryrun_baseline")

ARCH_ORDER = ["qwen2.5-14b", "olmo-1b", "yi-34b", "starcoder2-15b",
              "musicgen-medium", "rwkv6-1.6b", "zamba2-1.2b",
              "paligemma-3b", "arctic-480b", "kimi-k2-1t-a32b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath):
    cells = {}
    for f in glob.glob(os.path.join(dirpath, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"],
               "int8" if r.get("int8") else r.get("packed", False))
        cells[key] = r
    return cells


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(cells, mesh="pod"):
    print(f"\n### Roofline table ({mesh} mesh, per device, one step)\n")
    print("| arch × shape | compute | memory (fused est.) | collective | "
          "bottleneck | useful-flops | roofline frac | HBM/dev | fits 16G |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = cells.get((a, s, mesh, False))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {a} × {s} | — | — | — | skip (full attn @500k) "
                      f"| — | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {a} × {s} | ERROR | | | | | | | |")
                continue
            t = r["roofline"]
            m = r["memory"]
            tot = m.get("total_adjusted", m.get("total_per_device", 0))
            fits = "yes" if tot <= 16e9 else f"NO ({tot/1e9:.0f}G)"
            print(f"| {a} × {s} | {fmt_s(t['compute_s'])} "
                  f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
                  f"| {t['bottleneck']} | {t['useful_flops_ratio']:.2f} "
                  f"| **{t['roofline_fraction']:.3f}** "
                  f"| {tot/1e9:.1f}G | {fits} |")


def dryrun_table(cells):
    print("\n### Dry-run status (lower+compile), both meshes\n")
    print("| arch | shape | pod 16×16 | multipod 2×16×16 | compile s "
          "(pod/multi) | args+out bytes/dev (pod) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rp = cells.get((a, s, "pod", False))
            rm = cells.get((a, s, "multipod", False))
            if rp is None and rm is None:
                continue

            def st(r):
                if r is None:
                    return "—"
                return {"ok": "✓", "skipped": "skip",
                        "error": "✗"}.get(r["status"], "?")

            cs = f"{rp.get('compile_s','—') if rp else '—'}/" \
                 f"{rm.get('compile_s','—') if rm else '—'}"
            io = "—"
            if rp and rp["status"] == "ok":
                io = f"{rp['memory']['argument_size_in_bytes']/1e9:.2f}G"
            print(f"| {a} | {s} | {st(rp)} | {st(rm)} | {cs} | {io} |")


def packed_table(cells_all, mesh="pod"):
    """Dense vs DBB-packed vs DBB-INT8 decode cells (the paper's win)."""
    rows = {}
    for (a, s, m, p), r in cells_all.items():
        if s != "decode_32k" or m != mesh or r.get("status") != "ok":
            continue
        key = "int8" if r.get("int8") else ("dbb" if p else "dense")
        rows.setdefault(a, {})[key] = r
    if not any("dbb" in v for v in rows.values()):
        return
    print("\n### DBB-packed serving (decode_32k, pod): weight-stream saving\n")
    print("| arch | dense memory_s | DBB-packed | DBB+INT8 | io bytes "
          "dense→packed→int8 | fits 16G (dense→int8) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        v = rows.get(a)
        if not v or "dense" in v and "dbb" not in v:
            continue
        if "dense" not in v:
            continue

        def g(k, f, default="—"):
            return f(v[k]) if k in v else default

        io = "→".join(
            g(k, lambda r: f"{r['roofline']['io_bytes']/1e9:.2f}G")
            for k in ("dense", "dbb", "int8"))
        fits = "→".join(
            g(k, lambda r: "yes" if r["memory"].get(
                "total_adjusted", 0) <= 16e9 else
                f"NO({r['memory']['total_adjusted']/1e9:.0f}G)")
            for k in ("dense", "int8"))
        print(f"| {a} "
              f"| {g('dense', lambda r: fmt_s(r['roofline']['memory_s']))} "
              f"| {g('dbb', lambda r: fmt_s(r['roofline']['memory_s']))} "
              f"| {g('int8', lambda r: fmt_s(r['roofline']['memory_s']))} "
              f"| {io} | {fits} |")


def delta_table(cells, base_cells, mesh="pod"):
    print("\n### Baseline → optimized deltas (train_4k cells)\n")
    print("| arch | collective (before→after) | roofline frac "
          "(before→after) | total mem/dev (before→after) |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        r = cells.get((a, "train_4k", mesh, False))
        b = base_cells.get((a, "train_4k", mesh, False))
        if not r or not b or r["status"] != "ok" or b["status"] != "ok":
            continue
        tb, ta = b["roofline"], r["roofline"]
        mb = b["memory"].get("total_per_device", 0)
        ma = r["memory"].get("total_adjusted",
                             r["memory"].get("total_per_device", 0))
        print(f"| {a} | {fmt_s(tb['collective_s'])} → "
              f"{fmt_s(ta['collective_s'])} "
              f"| {tb['roofline_fraction']:.3f} → "
              f"**{ta['roofline_fraction']:.3f}** "
              f"| {mb/1e9:.0f}G → {ma/1e9:.1f}G |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    cells = load(ART)
    dryrun_table(cells)
    roofline_table(cells, args.mesh)
    packed_table(cells, args.mesh)
    if os.path.isdir(BASE):
        delta_table(cells, load(BASE), args.mesh)
