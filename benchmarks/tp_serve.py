"""Tensor-parallel Pallas serving A/B (DESIGN.md §14): BENCH_tp.json.

Standalone (NOT a `benchmarks.run` section): the multi-device CPU mesh
needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
*before* jax imports, which the aggregator — whose earlier sections
already initialized jax — cannot provide. CI runs it directly:

    PYTHONPATH=src python benchmarks/tp_serve.py --smoke

Three engines over one ragged serving workload, token-parity-checked:

  * ``pallas_1dev``  — single-device Pallas fast path (the PR 6 engine).
  * ``xla_mesh``     — gemm_impl="xla" under the live mesh: the GSPMD
                       baseline the ISSUE names (XLA partitions the
                       global graph itself; no Pallas kernels).
  * ``tp_pallas``    — the §14 shard_map wrap: per-shard Pallas kernels,
                       column→row-parallel pairs with one overlapped
                       all-reduce per block, KV heads sharded.

Two kinds of numbers land in the JSON:

  * **measured** tokens/sec for all three engines on this host. On a CPU
    host-platform mesh the "devices" are threads sharing one socket and
    interpret-mode Pallas dominates, so wall-clock TP "speedup" here is
    a smoke signal only — the parity assertions are the real content.
  * **modeled** per-device-step costs on TPU-v5e rooflines via
    `kernels.dispatch.explain` on a realistic serving shape (the same
    per-shard + collective-bytes cost model auto-dispatch ranks with):
    decode-step time at tp=1 vs tp=4 and the implied tokens/sec
    speedup — the ≥ 1.5× acceptance claim — plus the collective bytes
    per decode step each TP step moves vs the XLA-mesh baseline (GSPMD
    emits the same boundary reductions but gathers full-vocab logits
    for the greedy head instead of the vocab-parallel scalar combine).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEVICES = 4


def _setup_devices(n: int) -> None:
    assert "jax" not in sys.modules, \
        "tp_serve must set XLA_FLAGS before jax imports (run standalone)"
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


# ---------------------------------------------------------------------------
# measured: smoke-model serving on the host mesh
# ---------------------------------------------------------------------------

def _measured(smoke: bool) -> dict:
    import jax
    import numpy as np

    from repro.config import DbbConfig, ModelConfig
    from repro.dist.mesh_ctx import use_mesh
    from repro.models import registry
    from repro.serve.engine import ServeEngine

    cfg = ModelConfig(
        family="dense_lm", d_model=64, d_ff=256, num_layers=2,
        num_heads=8, num_kv_heads=4, vocab_size=128, dtype="float32",
        gemm_impl="pallas", kv_page_size=8,
        dbb=DbbConfig(enabled=True, block=8, nnz=4))
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 6 if smoke else 16
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size - 1,
                                          size=int(ln))))
               for ln in rng.integers(3, 12, size=n_req)]
    budget = 8
    mesh = jax.make_mesh((1, DEVICES), ("data", "model"))

    def timed(engine_ctx, cfg_run):
        with engine_ctx:
            # eos outside the vocab: the random smoke model must decode
            # every budgeted token or tokens/sec measures early stops
            eng = ServeEngine(cfg_run, params, max_batch=4,
                              eos_id=cfg_run.vocab_size)
            tp_reason = getattr(eng, "tp_reason", "n/a")
            eng.serve(prompts[:2], max_new_tokens=2)      # warm compile
            t0 = time.perf_counter()
            toks = eng.serve(prompts, max_new_tokens=budget)
            wall = time.perf_counter() - t0
        n_tok = sum(len(t) for t in toks)     # serve() returns generated
        return toks, {"tokens_per_s": round(n_tok / wall, 2),
                      "wall_s": round(wall, 3), "new_tokens": n_tok,
                      "tp_reason": tp_reason}

    import contextlib
    ref, row_1dev = timed(contextlib.nullcontext(), cfg)
    xla_toks, row_xla = timed(use_mesh(mesh),
                              cfg.replace(gemm_impl="xla"))
    tp_toks, row_tp = timed(use_mesh(mesh), cfg)

    assert row_tp["tp_reason"] == "", row_tp["tp_reason"]
    assert tp_toks == ref, "TP Pallas diverged from single-device Pallas"
    assert xla_toks == ref, "XLA-mesh baseline diverged"
    return {"workload": {"n_req": n_req, "max_new_tokens": budget,
                         "devices": DEVICES},
            "pallas_1dev": row_1dev, "xla_mesh": row_xla,
            "tp_pallas": row_tp, "token_parity": True}


# ---------------------------------------------------------------------------
# modeled: TPU-v5e roofline of a realistic decode step
# ---------------------------------------------------------------------------

def _decode_step_gemms(d_model: int, d_ff: int, n_heads: int, n_kv: int,
                       head_dim: int, batch: int):
    """(name, m, k, n, collective) per layer-block GEMV of one decode
    step, GLOBAL dims — explain's tp splits them per `_shard_dims`
    (column-parallel N split; row-parallel K split behind the declared
    all-reduce, the Megatron column→row pairing)."""
    qkv_n = (n_heads + 2 * n_kv) * head_dim
    return [
        ("qkv_proj", batch, d_model, qkv_n, ""),
        ("o_proj", batch, n_heads * head_dim, d_model, "all-reduce"),
        ("mlp_up", batch, d_model, 2 * d_ff, ""),
        ("mlp_down", batch, d_ff, d_model, "all-reduce"),
    ]


def _modeled() -> dict:
    from repro.config import ModelConfig
    from repro.kernels import dispatch

    # llama-8B-ish decode shapes: the regime the wrap targets
    d_model, d_ff, n_heads, n_kv, head_dim = 4096, 14336, 32, 8, 128
    vocab, batch, seq, layers = 128256, 8, 2048, 32
    cfg = ModelConfig(family="dense_lm", gemm_impl="pallas")
    gemms = _decode_step_gemms(d_model, d_ff, n_heads, n_kv, head_dim,
                               batch)

    def step(tp: int) -> dict:
        total_s, coll_bytes, routes = 0.0, 0.0, {}
        for name, m, k, n, coll in gemms:
            dec = dispatch.explain("matmul", m=m, k=k, n=n, cfg=cfg,
                                   tp=tp, collective=coll, gemv=True)
            d = next(x for x in dec if x.chosen)
            total_s += d.cost_s
            coll_bytes += d.collective_bytes
            routes[name] = d.name
        # decode attention shards KV *heads*, not a GEMM axis: each
        # device runs B · Hkv/tp paged-decode instances on full (G, D,
        # Smax) dims — scale the per-instance cost by the local count
        att = next(x for x in dispatch.explain(
            "attn_decode", m=n_heads // n_kv, k=head_dim, n=seq,
            cfg=cfg, page=16) if x.chosen)
        total_s += att.cost_s * batch * (n_kv // tp)
        routes["attn_decode"] = att.name
        # vocab-parallel greedy head: column-split GEMV + the [tp, B]
        # scalar combine (vs the XLA-mesh baseline's full-logit gather)
        head = next(x for x in dispatch.explain(
            "matmul", m=batch, k=d_model, n=vocab, cfg=cfg, tp=tp,
            gemv=True) if x.chosen)
        routes["lm_head"] = head.name
        head_comb = 2 * tp * batch * 4.0 if tp > 1 else 0.0
        step_s = layers * total_s + head.cost_s
        return {"step_us": round(step_s * 1e6, 2),
                "tokens_per_s_per_batch": round(batch / step_s, 1),
                "collective_bytes_per_step":
                    layers * coll_bytes + head_comb,
                "routes": routes}

    one, four = step(1), step(4)
    # GSPMD baseline moves the same per-layer all-reduces but all-gathers
    # the [B, vocab] logits for its greedy head (no scalar combine)
    xla_mesh_coll = (four["collective_bytes_per_step"]
                     - 2 * 4 * batch * 4.0 + batch * vocab * 4.0)
    return {
        "shape": {"d_model": d_model, "d_ff": d_ff, "heads": n_heads,
                  "kv_heads": n_kv, "vocab": vocab, "batch": batch,
                  "kv_len": seq, "layers": layers, "hw": "tpu-v5e"},
        "tp1": one, "tp4": four,
        "xla_mesh_collective_bytes_per_step": xla_mesh_coll,
        "speedup_tp4_vs_1dev": round(
            four["tokens_per_s_per_batch"] / one["tokens_per_s_per_batch"],
            2),
    }


def main(argv=None) -> int:
    global DEVICES
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload (CI mode)")
    ap.add_argument("--devices", type=int, default=DEVICES)
    ap.add_argument("--out", default="BENCH_tp.json")
    args = ap.parse_args(argv)
    DEVICES = args.devices
    _setup_devices(args.devices)

    report = {"tp_serve": {"measured": _measured(args.smoke),
                           "modeled_v5e": _modeled()}}
    speedup = report["tp_serve"]["modeled_v5e"]["speedup_tp4_vs_1dev"]
    ok = speedup >= 1.5
    report["tp_serve"]["ok"] = ok
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report["tp_serve"]["measured"], indent=2))
    print(f"modeled v5e decode speedup tp4 vs 1dev: {speedup}x "
          f"({'OK' if ok else 'BELOW 1.5x FLOOR'})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
