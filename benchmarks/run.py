"""Benchmark aggregator: one section per paper artifact.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] [--out DIR]

Every section returns a JSON-serializable dict; the kernel-perf sections
(implicit-GEMM conv A/B + fused-epilogue A/B) are written to
``BENCH_conv.json``, the decode/serving section (continuous batching
vs the per-token static loop + packed-weight residency, DESIGN.md §9) to
``BENCH_decode.json``, the attention section (flash vs chunked +
paged-KV occupancy, DESIGN.md §10) to ``BENCH_attn.json``, and the
kernel-dispatch section (auto vs forced routes across the decode/
prefill/conv shape grid, DESIGN.md §11) to ``BENCH_dispatch.json``, and
the packed-prefill section (pad-FLOP elimination + chunked-prefill TTFT,
DESIGN.md §12) to ``BENCH_packed.json``, and the sampling/speculative
section (tokens/step vs draft-k + the fused-epilogue A/B, DESIGN.md §15)
to ``BENCH_sampling.json``, and the INT4 weight-streaming section
(footprint/roofline/accuracy A/B vs INT8-DBB, DESIGN.md §16) to
``BENCH_quant.json`` so the perf trajectory is machine-readable
run-over-run (CI runs ``--smoke``, which executes only those sections on
reduced shapes and still emits all seven files).

table1 (DBB accuracy) trains small CNNs and takes a few minutes on CPU;
--fast trims step counts.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

# sections whose rows land in BENCH_conv.json (the perf trajectory file)
_PERF_SECTIONS = ("conv_gemm", "fused_epilogue")
# sections whose rows land in BENCH_decode.json (serving trajectory)
_DECODE_SECTIONS = ("decode_serve",)
# sections whose rows land in BENCH_attn.json (attention/paged-KV, §10)
_ATTN_SECTIONS = ("attn_paged",)
# sections whose rows land in BENCH_dispatch.json (route selection, §11)
_DISPATCH_SECTIONS = ("dispatch_routes",)
# sections whose rows land in BENCH_packed.json (packed prefill, §12)
_PACKED_SECTIONS = ("packed_prefill",)
# sections whose rows land in BENCH_sampling.json (sampling + spec, §15)
_SAMPLING_SECTIONS = ("spec_decode",)
# sections whose rows land in BENCH_quant.json (INT4 weight stream, §16)
_QUANT_SECTIONS = ("quant_stream",)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="kernel-perf sections only, reduced shapes "
                         "(CI mode); still writes BENCH_conv.json")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_conv.json")
    args = ap.parse_args(argv)
    fast = args.fast or args.smoke

    from benchmarks import (attn_paged, conv_gemm, decode_serve,
                            dispatch_routes, fig4_layers, fig5_sweep,
                            fused_epilogue, packed_prefill,
                            quant_stream, roofline_bench, spec_decode,
                            table1_dbb_accuracy, table2_efficiency)

    sections = [
        ("conv_gemm (implicit vs materialized im2col)",
         "conv_gemm", lambda: conv_gemm.run(fast=fast)),
        ("fused_epilogue (STA/DBB fused epilogue A/B)",
         "fused_epilogue", lambda: fused_epilogue.run(fast=fast)),
        ("decode_serve (continuous batching + packed streaming decode)",
         "decode_serve", lambda: decode_serve.run(fast=fast)),
        ("attn_paged (flash vs chunked + paged-KV occupancy)",
         "attn_paged", lambda: attn_paged.run(fast=fast)),
        ("dispatch_routes (auto vs forced kernel routes, §11)",
         "dispatch_routes", lambda: dispatch_routes.run(fast=fast)),
        ("packed_prefill (padding-free admission + chunked prefill, §12)",
         "packed_prefill", lambda: packed_prefill.run(fast=fast)),
        ("spec_decode (sampling head + self-speculative decode, §15)",
         "spec_decode", lambda: spec_decode.run(fast=fast)),
        ("quant_stream (INT4 groupwise weight streaming, §16)",
         "quant_stream", lambda: quant_stream.run(fast=fast)),
        ("table2_efficiency (paper Table II)",
         "table2_efficiency", lambda: table2_efficiency.run()),
        ("fig5_sweep (paper Fig. 5)", "fig5_sweep",
         lambda: fig5_sweep.run()),
        ("fig4_layers (paper Fig. 4)", "fig4_layers",
         lambda: fig4_layers.run()),
        ("table1_dbb_accuracy (paper Table I)", "table1_dbb_accuracy",
         lambda: table1_dbb_accuracy.run(steps=30 if fast else 60)),
        ("roofline (dry-run artifacts)", "roofline",
         lambda: roofline_bench.run()),
    ]
    if args.smoke:
        sections = [s for s in sections
                    if s[1] in (_PERF_SECTIONS + _DECODE_SECTIONS
                                + _ATTN_SECTIONS + _DISPATCH_SECTIONS
                                + _PACKED_SECTIONS + _SAMPLING_SECTIONS
                                + _QUANT_SECTIONS)]

    failures, results = [], {}
    for name, key, fn in sections:
        if any(s in name for s in args.skip):
            print(f"\n=== {name}: SKIPPED ===")
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            results[key] = fn()
            print(f"--- ok in {time.time() - t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()

    perf = {k: results[k] for k in _PERF_SECTIONS if k in results}
    if perf:
        path = os.path.join(args.out, "BENCH_conv.json")
        with open(path, "w") as f:
            json.dump(perf, f, indent=1, sort_keys=True)
        print(f"\nwrote {path}")
    dec = {k: results[k] for k in _DECODE_SECTIONS if k in results}
    if dec:
        path = os.path.join(args.out, "BENCH_decode.json")
        with open(path, "w") as f:
            json.dump(dec, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    att = {k: results[k] for k in _ATTN_SECTIONS if k in results}
    if att:
        path = os.path.join(args.out, "BENCH_attn.json")
        with open(path, "w") as f:
            json.dump(att, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    dsp = {k: results[k] for k in _DISPATCH_SECTIONS if k in results}
    if dsp:
        path = os.path.join(args.out, "BENCH_dispatch.json")
        with open(path, "w") as f:
            json.dump(dsp, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    pkd = {k: results[k] for k in _PACKED_SECTIONS if k in results}
    if pkd:
        path = os.path.join(args.out, "BENCH_packed.json")
        with open(path, "w") as f:
            json.dump(pkd, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    smp = {k: results[k] for k in _SAMPLING_SECTIONS if k in results}
    if smp:
        path = os.path.join(args.out, "BENCH_sampling.json")
        with open(path, "w") as f:
            json.dump(smp, f, indent=1, sort_keys=True)
        print(f"wrote {path}")
    qnt = {k: results[k] for k in _QUANT_SECTIONS if k in results}
    if qnt:
        path = os.path.join(args.out, "BENCH_quant.json")
        with open(path, "w") as f:
            json.dump(qnt, f, indent=1, sort_keys=True)
        print(f"wrote {path}")

    if failures:
        print(f"\nFAILED sections: {failures}")
        return 1
    print("\nall benchmark sections passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
