"""Benchmark aggregator: one section per paper artifact.

    PYTHONPATH=src python -m benchmarks.run [--fast]

table1 (DBB accuracy) trains small CNNs and takes a few minutes on CPU;
--fast trims step counts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip")
    args = ap.parse_args(argv)

    from benchmarks import (fig4_layers, fig5_sweep, fused_epilogue,
                            roofline_bench, table1_dbb_accuracy,
                            table2_efficiency)

    sections = [
        ("table2_efficiency (paper Table II)",
         lambda: table2_efficiency.run()),
        ("fig5_sweep (paper Fig. 5)", lambda: fig5_sweep.run()),
        ("fig4_layers (paper Fig. 4)", lambda: fig4_layers.run()),
        ("fused_epilogue (STA/DBB fused epilogue A/B)",
         lambda: fused_epilogue.run(fast=args.fast)),
        ("table1_dbb_accuracy (paper Table I)",
         lambda: table1_dbb_accuracy.run(steps=30 if args.fast else 60)),
        ("roofline (dry-run artifacts)", lambda: roofline_bench.run()),
    ]
    failures = []
    for name, fn in sections:
        if any(s in name for s in args.skip):
            print(f"\n=== {name}: SKIPPED ===")
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn()
            print(f"--- ok in {time.time() - t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED sections: {failures}")
        return 1
    print("\nall benchmark sections passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
