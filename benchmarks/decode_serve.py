"""Decode/serving A/B (DESIGN.md §9): tokens/sec and weight residency.

Two measurements on one DBB-packed smoke LM:

1. **Scheduling + sync**: the pre-PR serving loop (static waves padded to
   `max_batch`, one ``np.asarray`` host round-trip per decoded token)
   against the continuous-batching engine (mid-stream admission, chunked
   device-side token fetch). Same jitted decode step underneath — the A/B
   isolates the serving layer. With a mixed short/long workload the static
   wave drains to its slowest request while finished slots idle; the
   continuous scheduler backfills them.

2. **Weight residency**: HBM bytes of the stacked layer weights packed
   (values + bitmask, what the streaming decode path reads per token)
   vs dense, and the structural no-materialization assertion — tracing the
   Pallas-route decode step on packed params must hit `decompress_xla`
   ZERO times (every dense expand of a packed weight goes through it), so
   peak weight bytes per decoded token are the compressed bytes, not
   compressed + a dense transient. The XLA route is traced as a control
   (it must decompress per layer).

Emitted as the ``decode_serve`` section of ``BENCH_decode.json`` by
`benchmarks.run` (CI smoke-runs it alongside ``BENCH_conv.json``).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

SPEEDUP_FLOOR = 1.3     # acceptance: continuous ≥ 1.3x the pre-PR loop


def _build(seed: int = 0):
    from repro.configs import get_config
    from repro.core.dbb_linear import pack_tree
    from repro.core.sparsity import apply_dbb_to_tree
    from repro.models import registry

    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    dbb = cfg.dbb.__class__(enabled=True, block=8, nnz=4)
    cfg = cfg.replace(dbb=dbb)
    params = registry.init_params(jax.random.PRNGKey(seed), cfg)
    proj = apply_dbb_to_tree(params, dbb, straight_through=False)
    packed = pack_tree(proj, dbb)
    return cfg, proj, packed


def _workload(n_req: int, rng: np.random.Generator):
    """Mixed decode lengths: one long request per arrival wave — the
    static scheduler drains every wave to that request while the finished
    slots idle; the continuous scheduler backfills them. Prompt lengths
    are fixed so both schedulers reuse one compiled prefill/decode shape —
    the A/B measures scheduling and host syncs, not compilation."""
    prompts = [list(rng.integers(2, 500, size=6)) for _ in range(n_req)]
    budgets = [64 if i % 4 == 0 else 4 for i in range(n_req)]
    return prompts, budgets


def _static_per_token(eng, prompts: List[List[int]], budgets: List[int]
                      ) -> List[List[int]]:
    """The pre-PR serving loop: requests in arrival-order waves of
    `max_batch`, one prefill per wave, then a decode loop with ONE HOST
    SYNC PER TOKEN (`np.asarray(cur)`) and no slot backfill — finished
    rows ride along until the wave's longest request drains."""
    from repro.models import registry

    outs: List[List[int]] = []
    mb = eng.max_batch
    for w0 in range(0, len(prompts), mb):
        wave_p = prompts[w0:w0 + mb]
        wave_b = budgets[w0:w0 + mb]
        b = len(wave_p)
        max_len = max(len(p) for p in wave_p)
        total = max_len + max(wave_b)
        toks = np.zeros((mb, max_len), np.int32)
        start = np.zeros((mb,), np.int32)
        for i, p in enumerate(wave_p):
            toks[i, max_len - len(p):] = p
            start[i] = max_len - len(p)
        cache = registry.init_cache(eng.cfg, mb, total)
        batch = {"tokens": jnp.asarray(toks)}
        if start.any():
            batch["start"] = jnp.asarray(start)
        cur, cache = eng._prefill(eng.params, cache, batch)
        wave_outs: List[List[int]] = [[] for _ in range(b)]
        done = np.zeros(mb, bool)
        for _ in range(max(wave_b)):
            host = np.asarray(cur)                  # per-token host sync
            for i in range(b):
                if not done[i]:
                    wave_outs[i].append(int(host[i]))
                    done[i] |= (host[i] == eng.eos_id
                                or len(wave_outs[i]) >= wave_b[i])
            if done[:b].all():
                break
            cur, cache = eng._decode(eng.params, cache, cur)
        outs.extend(wave_outs)
    return outs


def _residency(cfg, packed, proj) -> Dict:
    """Packed vs dense stacked-layer HBM bytes + the structural assertion
    that the Pallas-route decode step never materializes a dense copy of a
    stacked layer weight."""
    from repro.core import dbb_linear
    from repro.core.dbb_linear import tree_footprint_bytes
    from repro.models import registry
    from repro.serve.engine import make_decode_step

    packed_bytes = tree_footprint_bytes(packed["layers"])
    dense_bytes = tree_footprint_bytes(proj["layers"])
    tok = jnp.asarray([7], jnp.int32)

    def trace_calls(route_cfg) -> int:
        cache = registry.init_cache(route_cfg, 1, 8)
        step = make_decode_step(route_cfg)
        before = dbb_linear.DECOMPRESS_STATS["calls"]
        jax.eval_shape(step, packed, cache, tok)    # trace, don't run
        return dbb_linear.DECOMPRESS_STATS["calls"] - before

    pallas_calls = trace_calls(cfg.replace(gemm_impl="pallas"))
    xla_calls = trace_calls(cfg.replace(gemm_impl="xla"))
    # peak-bytes assertion: on the streaming route the per-token weight
    # traffic (and residency) is the compressed bytes — a single
    # decompress_xla hit would mean a dense transient rode along
    assert pallas_calls == 0, (
        f"packed streaming decode materialized a dense weight "
        f"({pallas_calls} decompress calls traced)")
    assert xla_calls > 0, "control: the XLA route must decompress per layer"
    return {
        "layer_bytes_packed": int(packed_bytes),
        "layer_bytes_dense": int(dense_bytes),
        "packed_over_dense": round(packed_bytes / dense_bytes, 4),
        "pallas_route_dense_materializations": pallas_calls,
        "xla_route_dense_materializations": xla_calls,
    }


def _early_exit(eng, steps: int = 32) -> Dict:
    """All-done early exit inside the decode chunk (DESIGN.md §15): once
    every row's ``done`` flag is set mid-chunk, the remaining scan
    iterations take the `lax.cond` skip branch instead of the
    whole-model step. Measured directly on the jitted chunk: the same
    chunk timed with all rows live vs all rows already done — the gap is
    what a request that finishes early in a long chunk no longer pays."""
    from repro.models import registry

    mb = eng.max_batch
    chunk = eng._chunk_fn(steps)

    def once(done_val: bool) -> float:
        cache = registry.init_cache(eng.cfg, mb, 8 + steps + 1)
        toks = jnp.asarray(np.full((mb, 8), 7, np.int32))
        cur, cache = eng._prefill(eng.params, cache, {"tokens": toks})
        done = jnp.full((mb,), done_val)
        jax.block_until_ready(cur)
        t0 = time.perf_counter()
        out = chunk(eng.params, cache, cur, done)
        jax.block_until_ready(out[3])
        return time.perf_counter() - t0

    once(False), once(True)                      # compile both branches
    t_live = min(once(False) for _ in range(3))
    t_done = min(once(True) for _ in range(3))
    assert t_done < t_live, (
        f"all-done chunk ({t_done:.4f}s) not faster than a live one "
        f"({t_live:.4f}s) — the early-exit cond is not short-circuiting")
    return {"chunk_steps": steps,
            "live_chunk_s": round(t_live, 5),
            "all_done_chunk_s": round(t_done, 5),
            "skip_speedup": round(t_live / t_done, 2)}


def run(fast: bool = False) -> Dict:
    from repro.serve.engine import ServeEngine

    cfg, proj, packed = _build()
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 16
    prompts, budgets = _workload(n_req, rng)
    n_waves = -(-n_req // 4)
    static_steps = n_waves * max(budgets)
    cont_steps = -(-sum(budgets) // 4)
    # eos that greedy can't emit: decode length is budget-driven, so the
    # A/B measures scheduling, not random early stops
    eng = ServeEngine(cfg, packed, max_batch=4, eos_id=-1, fetch_chunk=8)

    # warmup: compile prefill/decode/chunk paths for both schedulers
    _static_per_token(eng, prompts[:4], budgets[:4])
    eng.serve(prompts[:4], budgets[:4])

    # best-of-3: decode steps are identical run-over-run, so the best wall
    # time is the least host-noise-contaminated one (shared CI runners)
    t_static = t_cont = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out_static = _static_per_token(eng, prompts, budgets)
        t_static = min(t_static, time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_cont = eng.serve(prompts, budgets)
        t_cont = min(t_cont, time.perf_counter() - t0)

    assert out_static == out_cont, "schedulers must emit identical tokens"
    n_tok = sum(len(o) for o in out_cont)
    tok_s_static = n_tok / t_static
    tok_s_cont = n_tok / t_cont
    speedup = tok_s_cont / tok_s_static
    row = {
        "n_requests": n_req,
        "max_batch": 4,
        "budgets_short_long": sorted(set(budgets)),
        "total_tokens": n_tok,
        "static_decode_steps_bound": static_steps,
        "continuous_decode_steps_bound": cont_steps,
        "static_per_token_tok_s": round(tok_s_static, 2),
        "continuous_chunked_tok_s": round(tok_s_cont, 2),
        "speedup": round(speedup, 3),
    }
    print(f"  static (per-token sync) : {tok_s_static:9.1f} tok/s")
    print(f"  continuous (chunked)    : {tok_s_cont:9.1f} tok/s "
          f"({speedup:.2f}x)")
    assert speedup >= SPEEDUP_FLOOR, (
        f"decode speedup {speedup:.2f}x below the {SPEEDUP_FLOOR}x floor")

    res = _residency(cfg, packed, proj)
    print(f"  layer weights packed/dense: {res['layer_bytes_packed']}/"
          f"{res['layer_bytes_dense']} B "
          f"({100 * res['packed_over_dense']:.1f}%), "
          f"dense materializations on streaming route: "
          f"{res['pallas_route_dense_materializations']}")
    ee = _early_exit(eng)
    print(f"  all-done early exit: live chunk {ee['live_chunk_s']*1e3:.1f}"
          f"ms vs done {ee['all_done_chunk_s']*1e3:.1f}ms "
          f"({ee['skip_speedup']:.1f}x skip)")
    return {"throughput": row, "residency": res, "early_exit": ee}


if __name__ == "__main__":
    run()
