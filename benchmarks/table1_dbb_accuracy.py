"""Paper Table I: accuracy of DBB-sparse training vs the dense baseline.

The container is offline, so ImageNet/CIFAR/MNIST are replaced by the
deterministic synthetic classification stream (data/pipeline.py) — deltas
are reported like-for-like (dense vs DBB on identical data/seed), which is
the quantity Table I demonstrates: DBB costs ≈0.1–1.1% accuracy.

Runs the paper's two small CNNs (LeNet-5, 5-layer ConvNet analogues) at
several density bounds, with amplitude pruning annealed mid-training
exactly as in §V-A (quantization-aware INT8 happens at pack time)."""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.config import DbbConfig, RunConfig, ShapeSpec, TrainConfig
from repro.configs import get_config
from repro.data.pipeline import SyntheticCNN
from repro.launch.train import train_loop
from repro.train.loop import make_eval_step


def _accuracy(run_cfg, state, n_batches=4):
    cfg = run_cfg.model
    # held-out: same data distribution (seed fixes the class prototypes),
    # unseen step indices
    pipe = SyntheticCNN(cfg, 64, seed=run_cfg.train.seed)
    ev = jax.jit(make_eval_step(
        run_cfg, nnz=cfg.dbb.nnz if cfg.dbb.enabled else None))
    accs = []
    for i in range(n_batches):
        b = {k: jax.numpy.asarray(v)
             for k, v in pipe.batch_at(100_000 + i).items()}
        accs.append(float(ev(state.params, b)["acc"]))
    return float(np.mean(accs))


def _train_one(arch: str, nnz: int | None, steps: int, seed: int = 0):
    cfg = get_config(arch, smoke=True)
    if nnz is None:
        cfg = cfg.replace(dbb=DbbConfig(enabled=False))
    else:
        cfg = cfg.replace(dbb=DbbConfig(enabled=True, block=8, nnz=nnz,
                                        apply_to=("conv",)))
    rc = RunConfig(model=cfg, train=TrainConfig(
        steps=steps, learning_rate=3e-3, log_every=10**9, seed=seed,
        dbb_prune_start=steps // 3, dbb_prune_ramp=steps // 3))
    shape = ShapeSpec("t", 16, 32, "train")
    state, _ = train_loop(rc, shape, log=lambda *_: None)
    return _accuracy(rc, state)


def run(quiet: bool = False, steps: int = 60) -> dict:
    rows = []
    for arch in ("lenet5-dbb", "convnet-dbb"):
        base = _train_one(arch, None, steps)
        for nnz, label in ((2, "25%"), (3, "37.5%"), (4, "50%")):
            acc = _train_one(arch, nnz, steps)
            rows.append({"model": arch, "nnz_pct": label,
                         "dense_acc": round(base, 4),
                         "dbb_acc": round(acc, 4),
                         "delta": round(base - acc, 4)})
            if not quiet:
                print(f"{arch:14s} NNZ<= {label:6s} dense {base:.3f} "
                      f"dbb {acc:.3f} delta {base - acc:+.3f}")
    worst = max(r["delta"] for r in rows)
    if not quiet:
        print(f"worst accuracy delta: {worst:+.3f} "
              f"(paper Table I range: 0.001-0.011)")
    return {"rows": rows, "worst_delta": worst}


def main(argv=None):
    return run()


if __name__ == "__main__":
    main()
