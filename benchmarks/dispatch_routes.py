"""Dispatch-quality benchmark: auto route vs every forced route (§11).

For each shape in a decode / prefill / conv grid the harness asks the
registry for its route table (`dispatch.explain`), runs **auto** dispatch
and every **forced** applicable route on the same operands, and records
best-of-N wall clock per route. The headline number per shape is

    auto_vs_best = auto_time / min(forced_times)

If auto leaves > ``REGRESSION_RTOL`` (10%) of wall clock on the table —
i.e. a forced route is more than 10% faster than what the cost model
picked — the row is flagged ``regression`` and `run()` counts it. On the
CPU interpret backend kernel timings are correctness-grade only, so
regressions WARN rather than fail (mirroring fused_epilogue.py); numerical
parity between every forced route and auto is asserted strictly either
way. The per-shape ``table`` field carries the explain() rows (modeled
cost, flops, bytes, applicability reasons) so BENCH_dispatch.json shows
*why* each route was ranked where it was.

Run:  PYTHONPATH=src python -m benchmarks.dispatch_routes [--fast]
(benchmarks.run wires this into BENCH_dispatch.json; CI smoke-runs it.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

REGRESSION_RTOL = 0.10

# (tag, m, k, n, packed) — decode / prefill regimes across the GEMM grid;
# head_gemv rows carry the gemv hint greedy_from_hidden uses in production
# (skinny at B <= 32, xla above — both regimes measured)
SHAPES = [
    ("decode_dense", 4, 1024, 1024, False),
    ("decode_packed", 4, 1024, 1024, True),
    ("prefill_dense", 512, 512, 1024, False),
    ("prefill_packed", 512, 512, 1024, True),
    ("head_gemv", 8, 512, 8192, False),
    ("head_gemv_large", 48, 512, 8192, False),
]
FAST_SHAPES = [
    ("decode_dense", 4, 256, 256, False),
    ("decode_packed", 4, 256, 256, True),
    ("prefill_dense", 128, 128, 256, False),
    ("prefill_packed", 128, 128, 256, True),
    ("head_gemv", 8, 128, 1024, False),
    ("head_gemv_large", 48, 128, 1024, False),
]
# (tag, batch, img, cin, cout, k) — cout lane-aligned so the implicit
# kernel is the modeled winner (degenerate cout pads N 4x+ and the table
# rightly hands those to the im2col oracle)
CONV_SHAPES = [("conv_dense", 2, 16, 32, 128, 3),
               ("conv_packed", 2, 16, 32, 128, 3)]
FAST_CONV_SHAPES = [("conv_dense", 1, 8, 16, 128, 3),
                    ("conv_packed", 1, 8, 16, 128, 3)]


def _best_of(fn, n: int = 3) -> float:
    jax.block_until_ready(fn())            # compile + warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _table_rows(decisions):
    return [{"route": d.name, "applicable": d.applicable,
             "reason": d.reason, "chosen": d.chosen,
             "cost_s": d.cost_s, "flops": d.flops, "bytes": d.bytes}
            for d in decisions]


def bench_matmul(tag, m, k, n, packed, repeats=3) -> dict:
    from repro.core.dbb import pack_dbb
    from repro.kernels import dispatch

    # head-GEMV rows measure the exact dispatch greedy_from_hidden issues
    gemv = tag.startswith("head_gemv")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w_dense = jax.random.normal(jax.random.fold_in(key, 1), (k, n),
                                jnp.float32)
    w = pack_dbb(w_dense, 8, 4) if packed else w_dense
    bias = jnp.ones((n,), jnp.float32)

    # epilogue_ops mirrors the dispatch.matmul call below (bias + relu):
    # the table must describe the dispatch it is compared against
    decisions = dispatch.explain("matmul", m=m, k=k, n=n, packed=packed,
                                 pallas=True, gemv=gemv, epilogue_ops=2)
    auto_fn = jax.jit(lambda: dispatch.matmul(x, w, bias, act="relu",
                                              pallas=True, gemv=gemv))
    ref = np.asarray(auto_fn())
    auto_t = _best_of(auto_fn, repeats)

    forced = {}
    for d in decisions:
        if not d.applicable:
            continue
        fn = jax.jit(lambda name=d.name: dispatch.matmul(
            x, w, bias, act="relu", pallas=True, gemv=gemv, route=name))
        got = np.asarray(fn())
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{tag}:{d.name}")
        forced[d.name] = _best_of(fn, repeats)

    best_name = min(forced, key=forced.get)
    ratio = auto_t / forced[best_name]
    return {
        "tag": tag, "m": m, "k": k, "n": n, "packed": packed,
        "auto_route": next(d.name for d in decisions if d.chosen),
        "auto_s": auto_t, "forced_s": forced,
        "best_forced": best_name, "auto_vs_best": ratio,
        "regression": ratio > 1.0 + REGRESSION_RTOL,
        "table": _table_rows(decisions),
    }


def bench_conv(tag, b, img, cin, cout, kk, repeats=3) -> dict:
    from repro.core.dbb import pack_dbb
    from repro.kernels import dispatch

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, img, img, cin), jnp.float32)
    w_dense = jax.random.normal(jax.random.fold_in(key, 1),
                                (kk * kk * cin, cout), jnp.float32)
    packed = tag.endswith("packed")
    w = pack_dbb(w_dense, 8, 4) if packed else w_dense
    bias = jnp.ones((cout,), jnp.float32)

    decisions = dispatch.explain(
        "conv", m=b * img * img, k=kk * kk * cin, n=cout, packed=packed,
        pallas=True, conv_geom=(b, img, img, cin, kk, kk, 1),
        epilogue_ops=2)
    auto_fn = jax.jit(lambda: dispatch.conv(x, w, bias, kh=kk, kw=kk,
                                            act="relu"))
    ref = np.asarray(auto_fn())
    auto_t = _best_of(auto_fn, repeats)

    forced = {}
    for d in decisions:
        if not d.applicable:
            continue
        fn = jax.jit(lambda name=d.name: dispatch.conv(
            x, w, bias, kh=kk, kw=kk, act="relu", route=name))
        got = np.asarray(fn())
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{tag}:{d.name}")
        forced[d.name] = _best_of(fn, repeats)

    best_name = min(forced, key=forced.get)
    ratio = auto_t / forced[best_name]
    return {
        "tag": tag, "b": b, "img": img, "cin": cin, "cout": cout, "k": kk,
        "auto_route": next(d.name for d in decisions if d.chosen),
        "auto_s": auto_t, "forced_s": forced,
        "best_forced": best_name, "auto_vs_best": ratio,
        "regression": ratio > 1.0 + REGRESSION_RTOL,
        "table": _table_rows(decisions),
    }


def run(fast: bool = False) -> dict:
    shapes = FAST_SHAPES if fast else SHAPES
    conv_shapes = FAST_CONV_SHAPES if fast else CONV_SHAPES
    rows = []
    for tag, m, k, n, packed in shapes:
        r = bench_matmul(tag, m, k, n, packed)
        rows.append(r)
        print(f"{tag:16s} auto={r['auto_route']:<12s} "
              f"{r['auto_s'] * 1e3:8.2f} ms  best_forced="
              f"{r['best_forced']:<12s} ratio={r['auto_vs_best']:.3f}"
              f"{'  REGRESSION' if r['regression'] else ''}")
    for tag, b, img, cin, cout, kk in conv_shapes:
        r = bench_conv(tag, b, img, cin, cout, kk)
        rows.append(r)
        print(f"{tag:16s} auto={r['auto_route']:<12s} "
              f"{r['auto_s'] * 1e3:8.2f} ms  best_forced="
              f"{r['best_forced']:<12s} ratio={r['auto_vs_best']:.3f}"
              f"{'  REGRESSION' if r['regression'] else ''}")

    regressions = [r["tag"] for r in rows if r["regression"]]
    if regressions:
        # interpret-mode timing noise is not a regression signal (see
        # fused_epilogue.py); on TPU this is where auto-dispatch quality
        # shows up run-over-run in BENCH_dispatch.json
        print(f"WARNING: auto leaves >{REGRESSION_RTOL:.0%} on the table "
              f"for {regressions} (interpret-mode timings)")
    else:
        print("auto dispatch within tolerance of best forced route on "
              "every shape")
    return {"rows": rows, "regressions": regressions,
            "regression_rtol": REGRESSION_RTOL,
            "backend": jax.default_backend()}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    run(fast=args.fast)
