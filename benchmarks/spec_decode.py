"""Sampling + self-speculative decode A/B (DESIGN.md §15).

Three measurements on one smoke LM:

1. **Tokens/step vs draft-k**: serve the same sampled workload at
   ``draft_k = 0`` (plain sampling) and increasing draft depths. The
   weights are made *acceptance-friendly* by zeroing every layer past
   the draft boundary — those layers become exact residual identities,
   so the truncated draft model agrees with the full model and the
   rejection-sampling verifier accepts nearly every draft. This is the
   regime where self-speculation pays: the A/B's speedup floor mirrors
   the continuous-batching benchmark's.

2. **Acceptance accounting**: the engine's `serve_stats` speculative
   counters (`spec_emitted / spec_steps`), reported as tokens/step and
   the per-draft acceptance rate.

3. **Penalty-epilogue A/B**: one skinny head-GEMV shape sampled through
   the fused Pallas epilogue route and through the XLA reference
   sampler — the streams must be bit-identical (the roofline costs of
   the two routes are what `BENCH_dispatch.json` tracks; here the check
   is semantic equivalence plus wall time for the record).

Emitted as the ``spec_decode`` section of ``BENCH_sampling.json`` by
`benchmarks.run` (CI smoke-runs it and uploads the artifact).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

SPEEDUP_FLOOR = 1.3     # acceptance: spec decode ≥ 1.3x plain sampling


def _identity_tail(params: Dict, nd: int) -> Dict:
    """Zero every stacked-layer leaf from layer ``nd`` on: those layers'
    attention/MLP blocks emit exact zeros, the residual stream passes
    through unchanged, and the truncated draft model computes the same
    logits as the full model — the acceptance-friendly regime."""
    def z(a):
        m = jnp.arange(a.shape[0]) < nd
        return a * m.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
    return dict(params, layers=jax.tree_util.tree_map(z, params["layers"]))


def _build(seed: int = 0):
    from repro.configs import get_config
    from repro.models import registry

    # deepen and widen the smoke config: self-speculation trades k cheap
    # truncated steps for one multi-token verify, which only pays when
    # the full model is meaningfully deeper than the draft (2 smoke
    # layers give a 1-layer draft that costs half a full step — no room
    # to win) and when per-step compute dominates the interpreter's
    # fixed per-op dispatch overhead (the smoke dims are overhead-bound)
    cfg = get_config("olmo-1b", smoke=True).replace(
        remat="none", num_layers=8, d_model=512, d_ff=1536,
        num_heads=8, num_kv_heads=8)
    params = registry.init_params(jax.random.PRNGKey(seed), cfg)
    nd = 1
    return cfg, _identity_tail(params, nd), nd


def _epilogue_ab(cfg, params) -> Dict:
    """Fused Pallas epilogue vs the XLA reference sampler on one skinny
    head shape: bit-identical tokens, wall time for the record."""
    from repro.kernels import dispatch
    from repro.models import registry

    pcfg = cfg.replace(gemm_impl="pallas")
    b, d = 4, cfg.d_model
    w = registry.lm_head_weight(params, cfg).astype(jnp.float32)
    v = w.shape[-1]
    h = jax.random.normal(jax.random.PRNGKey(1), (b, d), jnp.float32)
    counts = jnp.zeros((b, v), jnp.int32)
    row_f = jnp.full((b,), 0.7, jnp.float32)
    one = jnp.ones((b,), jnp.float32)
    zero = jnp.zeros((b,), jnp.float32)
    seeds = jnp.arange(b, dtype=jnp.int32)
    step = jnp.zeros((b,), jnp.int32)

    def call(route):
        return dispatch.head_sample(
            h, w, counts, row_f, one, zero, zero, seeds, step,
            cfg=pcfg, route=route)

    routes = {}
    toks = {}
    for route in ("head_sample_fused", "head_sample_xla"):
        fn = jax.jit(lambda r=route: call(r))
        tok = np.asarray(fn())                       # compile + run
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        routes[route] = round(time.perf_counter() - t0, 6)
        toks[route] = tok
    bit_equal = bool(
        (toks["head_sample_fused"] == toks["head_sample_xla"]).all())
    assert bit_equal, "fused epilogue diverged from the XLA sampler"
    return {"shape_bkn": [b, d, v], "bit_equal": bit_equal,
            "fused_s": routes["head_sample_fused"],
            "xla_s": routes["head_sample_xla"]}


def run(fast: bool = False) -> Dict:
    from repro.serve.engine import ServeEngine
    from repro.serve.sampling import SamplingParams

    cfg, params, nd = _build()
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 12
    max_new = 16 if fast else 24
    prompts = [list(rng.integers(2, 500, size=6)) for _ in range(n_req)]
    budgets = [max_new] * n_req
    sampling = [SamplingParams(temperature=0.7, seed=i)
                for i in range(n_req)]
    # eos greedy can't emit: decode length stays budget-driven, so the
    # A/B measures the step loop, not random early stops
    eng = ServeEngine(cfg, params, max_batch=4, eos_id=-1, fetch_chunk=4,
                      draft_layers=nd)

    rows: List[Dict] = []
    tok_s_by_k: Dict[int, float] = {}
    for k in (0, 2, 3):
        eng.serve(prompts[:4], budgets[:4], sampling=sampling[:4],
                  draft_k=k)                          # warmup/compile
        t_best, outs = float("inf"), None
        for _ in range(3):
            t0 = time.perf_counter()
            outs = eng.serve(prompts, budgets, sampling=sampling,
                             draft_k=k)
            t_best = min(t_best, time.perf_counter() - t0)
        n_tok = sum(len(o) for o in outs)
        tok_s = n_tok / t_best
        tok_s_by_k[k] = tok_s
        row = {"draft_k": k, "draft_layers": nd if k else 0,
               "total_tokens": n_tok, "tok_s": round(tok_s, 2)}
        if k:
            st = eng.serve_stats
            tps = st["spec_emitted"] / max(1, st["spec_steps"])
            row["tokens_per_step"] = round(tps, 3)
            row["acceptance_rate"] = round((tps - 1) / k, 3)
            row["speedup_vs_plain"] = round(tok_s / tok_s_by_k[0], 3)
        print(f"  draft_k={k}: {tok_s:9.1f} tok/s"
              + (f" ({row['tokens_per_step']:.2f} tok/step, "
                 f"acceptance {row['acceptance_rate']:.2f}, "
                 f"{row['speedup_vs_plain']:.2f}x)" if k else ""))
        rows.append(row)

    best = max(r.get("speedup_vs_plain", 0.0) for r in rows)
    assert best >= SPEEDUP_FLOOR, (
        f"speculative speedup {best:.2f}x below the {SPEEDUP_FLOOR}x "
        f"floor at acceptance-friendly settings")

    epi = _epilogue_ab(cfg, params)
    print(f"  fused epilogue vs XLA sampler: bit_equal={epi['bit_equal']} "
          f"({epi['fused_s']*1e3:.1f}ms vs {epi['xla_s']*1e3:.1f}ms)")
    return {"tokens_per_step": rows, "penalty_epilogue_ab": epi}


if __name__ == "__main__":
    run()
