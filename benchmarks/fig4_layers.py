"""Paper Fig. 4: per-layer efficiency on ResNet50_v1 GEMM shapes.

The paper lowers each conv layer to an im2col GEMM and reports area/power
efficiency per layer (62.5% sparse weights, varying activation sparsity,
conv1 dense). We reproduce both halves:
  * the analytical-model efficiency per layer (same methodology as Table II,
    with the layer's measured activation sparsity), and
  * the TPU-side counterpart: dense vs DBB GEMM through the Pallas kernels
    on the exact layer shapes, reporting HBM weight-traffic reduction and
    MXU utilization (the quantities the TPU adaptation actually improves).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.area_model import DesignPoint, evaluate_design
from repro.core.dbb import dbb_footprint_bytes, dense_footprint_bytes, pack_dbb
from repro.core.sta import mxu_utilization
from repro.kernels.dbb_gemm.ops import dbb_gemm_packed
from repro.kernels.sta_gemm.ops import sta_gemm

# ResNet50_v1 representative layers (paper Fig. 4), im2col GEMM shapes:
# (name, M = H*W spatial, K = kh*kw*Cin, N = Cout, act_sparsity)
RESNET50_LAYERS = [
    ("conv1",            12544, 147,  64, 0.00),   # stays dense (paper)
    ("blk1/unit1/conv2",  3136, 576,  64, 0.39),
    ("blk1/unit3/conv3",  3136, 64 * 9, 256, 0.50),
    ("blk2/unit2/conv2",   784, 1152, 128, 0.55),
    ("blk3/unit4/conv2",   196, 2304, 256, 0.65),
    ("blk4/unit1/conv2",    49, 4608, 512, 0.72),
    ("fc1000",               1, 2048, 1000, 0.75),
]

_B, _NNZ = 8, 3        # 1x8 DBB at 62.5% sparse weights (paper Fig. 4)


def run(quiet: bool = False, verify: bool = True) -> dict:
    base = evaluate_design(DesignPoint("SA 1x1x1", "sa"), act_sparsity=0.5)
    rows = []
    for name, m, k, n, act_sp in RESNET50_LAYERS:
        dense_here = name == "conv1"
        d = (DesignPoint("STA 4x8x4", "sta", a=4, b=8, c=4) if dense_here
             else DesignPoint("STA-DBB 4x8x4", "sta_dbb", a=4, b=8, c=4,
                              nnz=_NNZ, weight_sparsity=1 - _NNZ / _B))
        eff = evaluate_design(d, act_sparsity=act_sp)
        area_eff = base["area_per_eff_mac"] / eff["area_per_eff_mac"]
        power_eff = base["power_per_eff_mac"] / eff["power_per_eff_mac"]

        kp = ((k + _B - 1) // _B) * _B      # pad K to the DBB block grid
        w_dense = dense_footprint_bytes(kp, n)
        w_dbb = (w_dense if dense_here
                 else dbb_footprint_bytes(kp, n, _B, _NNZ))
        row = {"layer": name, "M": m, "K": k, "N": n,
               "act_sparsity": act_sp,
               "area_eff": round(area_eff, 2),
               "power_eff": round(power_eff, 2),
               "weight_bytes_dense": w_dense,
               "weight_bytes_dbb": w_dbb,
               "hbm_weight_saving": round(1 - w_dbb / w_dense, 4),
               "mxu_util": round(mxu_utilization(m, k, n), 3)}
        rows.append(row)

    if verify:   # numerical check of the kernel pair on one real layer shape
        name, m, k, n, _ = RESNET50_LAYERS[2]
        kp = ((k + _B - 1) // _B) * _B
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, kp), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (kp, n), jnp.float32)
        p = pack_dbb(w, _B, _NNZ)
        y_dense = sta_gemm(x, w)
        y_dbb = dbb_gemm_packed(x, p)
        from repro.core.dbb import dbb_project
        ref = x @ dbb_project(w, _B, _NNZ)
        np.testing.assert_allclose(np.asarray(y_dbb), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    if not quiet:
        for r in rows:
            print(f"{r['layer']:20s} M{r['M']:6d} K{r['K']:5d} N{r['N']:5d} "
                  f"area_eff {r['area_eff']:5.2f}x power_eff "
                  f"{r['power_eff']:5.2f}x  hbm_w_saving "
                  f"{r['hbm_weight_saving']:6.1%} mxu {r['mxu_util']:.2f}")
    return {"layers": rows}


def main(argv=None):
    return run()


if __name__ == "__main__":
    main()
