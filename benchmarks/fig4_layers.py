"""Paper Fig. 4: per-layer efficiency on ResNet50_v1 GEMM shapes.

The paper lowers each conv layer to an im2col GEMM and reports area/power
efficiency per layer (62.5% sparse weights, varying activation sparsity,
conv1 dense). We reproduce both halves:
  * the analytical-model efficiency per layer (same methodology as Table II,
    with the layer's measured activation sparsity), and
  * the TPU-side counterpart: dense vs DBB GEMM through the Pallas kernels
    on the exact layer shapes, reporting HBM weight-traffic reduction, MXU
    utilization, and — for the conv layers — the activation-HBM blowup the
    *implicit-GEMM* conv route (kernels.conv_gemm, DESIGN.md §8) avoids by
    never materializing the im2col patch matrix.

The numerical verify step runs the implicit-GEMM conv kernel (dense and
DBB-compressed weight stream) against the explicit im2col + GEMM lowering
on a real 3×3 layer geometry.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.area_model import DesignPoint, evaluate_design
from repro.core.dbb import dbb_footprint_bytes, dense_footprint_bytes, pack_dbb
from repro.core.sta import mxu_utilization
from repro.kernels.conv_gemm.ops import conv_gemm, conv_gemm_packed
from repro.kernels.conv_gemm.ref import im2col

# ResNet50_v1 representative layers (paper Fig. 4), im2col GEMM shapes:
# (name, M = H*W spatial, K = kh*kw*Cin, N = Cout, act_sparsity) plus the
# conv geometry (H, W, Cin, kh, stride) the GEMM was lowered from — None
# for the fc layer, which is a plain GEMM.
RESNET50_LAYERS = [
    ("conv1",            12544, 147,  64, 0.00, (224, 224, 3, 7, 2)),
    ("blk1/unit1/conv2",  3136, 576,  64, 0.39, (56, 56, 64, 3, 1)),
    ("blk1/unit3/conv3",  3136, 64 * 9, 256, 0.50, (56, 56, 64, 3, 1)),
    ("blk2/unit2/conv2",   784, 1152, 128, 0.55, (28, 28, 128, 3, 1)),
    ("blk3/unit4/conv2",   196, 2304, 256, 0.65, (14, 14, 256, 3, 1)),
    ("blk4/unit1/conv2",    49, 4608, 512, 0.72, (7, 7, 512, 3, 1)),
    ("fc1000",               1, 2048, 1000, 0.75, None),
]

_B, _NNZ = 8, 3        # 1x8 DBB at 62.5% sparse weights (paper Fig. 4)


def _conv_act_bytes(geom, itemsize: int = 1):
    """(im2col_bytes, implicit_bytes): the patch matrix the explicit
    lowering writes to HBM vs the padded input the implicit kernel reads
    in place (per image, INT8 serving bytes)."""
    h, w, c, k, s = geom
    ho, wo = -(-h // s), -(-w // s)
    im2col_b = ho * wo * k * k * c * itemsize
    implicit_b = ((ho - 1) * s + k) * ((wo - 1) * s + k) * c * itemsize
    return im2col_b, implicit_b


def run(quiet: bool = False, verify: bool = True) -> dict:
    base = evaluate_design(DesignPoint("SA 1x1x1", "sa"), act_sparsity=0.5)
    rows = []
    for name, m, k, n, act_sp, geom in RESNET50_LAYERS:
        dense_here = name == "conv1"
        d = (DesignPoint("STA 4x8x4", "sta", a=4, b=8, c=4) if dense_here
             else DesignPoint("STA-DBB 4x8x4", "sta_dbb", a=4, b=8, c=4,
                              nnz=_NNZ, weight_sparsity=1 - _NNZ / _B))
        eff = evaluate_design(d, act_sparsity=act_sp)
        area_eff = base["area_per_eff_mac"] / eff["area_per_eff_mac"]
        power_eff = base["power_per_eff_mac"] / eff["power_per_eff_mac"]

        kp = ((k + _B - 1) // _B) * _B      # pad K to the DBB block grid
        w_dense = dense_footprint_bytes(kp, n)
        w_dbb = (w_dense if dense_here
                 else dbb_footprint_bytes(kp, n, _B, _NNZ))
        row = {"layer": name, "M": m, "K": k, "N": n,
               "act_sparsity": act_sp,
               "area_eff": round(area_eff, 2),
               "power_eff": round(power_eff, 2),
               "weight_bytes_dense": w_dense,
               "weight_bytes_dbb": w_dbb,
               "hbm_weight_saving": round(1 - w_dbb / w_dense, 4),
               "mxu_util": round(mxu_utilization(m, k, n), 3)}
        if geom is not None:
            i2c_b, impl_b = _conv_act_bytes(geom)
            row["act_bytes_im2col"] = i2c_b
            row["act_bytes_implicit"] = impl_b
            row["im2col_blowup"] = round(i2c_b / impl_b, 2)
        rows.append(row)

    if verify:
        # implicit-GEMM conv kernel vs the explicit im2col + GEMM lowering
        # on a blk2-style geometry (28×28×64 → 128, 3×3), dense and DBB
        h = w = 28
        cin, cout, k = 64, 128, 3
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (1, h, w, cin), jnp.float32)
        wm = jax.random.normal(jax.random.fold_in(key, 1),
                               (k * k * cin, cout), jnp.float32)
        cols = im2col(x, k, k)
        ref = (cols.reshape(-1, k * k * cin) @ wm).reshape(1, h, w, cout)
        got = conv_gemm(x, wm, kh=k, kw=k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        from repro.core.dbb import dbb_project
        p = pack_dbb(wm, _B, _NNZ)
        got_dbb = conv_gemm_packed(x, p, kh=k, kw=k)
        ref_dbb = (cols.reshape(-1, k * k * cin)
                   @ dbb_project(wm, _B, _NNZ)).reshape(1, h, w, cout)
        np.testing.assert_allclose(np.asarray(got_dbb), np.asarray(ref_dbb),
                                   rtol=1e-4, atol=1e-4)

    if not quiet:
        for r in rows:
            blow = (f" im2col_blowup {r['im2col_blowup']:5.2f}x"
                    if "im2col_blowup" in r else "")
            print(f"{r['layer']:20s} M{r['M']:6d} K{r['K']:5d} N{r['N']:5d} "
                  f"area_eff {r['area_eff']:5.2f}x power_eff "
                  f"{r['power_eff']:5.2f}x  hbm_w_saving "
                  f"{r['hbm_weight_saving']:6.1%} mxu {r['mxu_util']:.2f}"
                  f"{blow}")
    return {"layers": rows}


def main(argv=None):
    return run()


if __name__ == "__main__":
    main()
