"""Packed (padding-free) prefill A/B (DESIGN.md §12): pad-FLOP
elimination and TTFT under chunked prefill.

Two measurements on one smoke LM over a ragged workload with a 4:1
max:median prompt-length ratio (the traffic shape where padded admission
hurts most):

1. **Pad-FLOP elimination**: prefill tokens actually entering the layer
   GEMMs under packed cu_seqlens admission (`serve_stats`'s
   ``packed_prefill_tokens`` — real tokens + power-of-two bucket
   rounding) vs the two padded baselines: the static-batch rectangle
   (``B × T_max`` per wave, what `generate()`-style admission pays) and
   the legacy per-slot bucket admission (each prompt left-padded to its
   own power-of-two bucket). Acceptance: ≥ 30% of the rectangle
   baseline's prefill FLOPs eliminated on the 4:1 mix.

2. **TTFT jitter under chunked prefill**: p50/p95 time-to-first-token
   across requests, whole-prompt packed calls (chunk=0) vs chunked
   (``--prefill-chunk``-style fixed token budget per scheduler
   iteration). Wall-clock on a shared CI box is noisy, so the run also
   records the deterministic jitter proxy ``max_prefill_call_tokens`` —
   the largest single prefill dispatch a decode step can stall behind —
   which chunking must bound by the chunk budget (+ bucket rounding).

Emitted as the ``packed_prefill`` section of ``BENCH_packed.json`` by
`benchmarks.run` (CI smoke-runs it and uploads the artifact).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

PAD_ELIM_FLOOR = 0.30    # acceptance: ≥ 30% of rectangle pad FLOPs gone


def _bucket(n: int, minimum: int = 8) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def _workload(n_req: int, rng: np.random.Generator, vocab: int):
    """4:1 max:median mix: one long prompt per group of four. Median
    length 9 (bucket 16), max 36 (bucket 64) — ragged against every
    power-of-two boundary so both padded baselines pay real padding."""
    lens = [36 if i % 4 == 0 else 9 for i in range(n_req)]
    prompts = [list(map(int, rng.integers(2, vocab - 1, size=ln)))
               for ln in lens]
    budgets = [6] * n_req
    return prompts, budgets


def _percentiles(xs: List[float]) -> Dict[str, float]:
    a = np.asarray([x for x in xs if np.isfinite(x)], np.float64)
    return {"p50_ms": round(float(np.percentile(a, 50)) * 1e3, 2),
            "p95_ms": round(float(np.percentile(a, 95)) * 1e3, 2)}


def run(fast: bool = False) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine

    cfg = get_config("olmo-1b", smoke=True).replace(remat="none")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 16
    max_batch = 4
    prompts, budgets = _workload(n_req, rng, cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_batch=max_batch)

    # -- pad-FLOP elimination (whole-prompt packed admission) ------------
    t0 = time.perf_counter()
    out_packed = eng.serve(prompts, budgets, prefill_mode="packed",
                           prefill_chunk=0)
    packed_wall = time.perf_counter() - t0
    stats0 = dict(eng.serve_stats)
    packed_tokens = stats0["packed_prefill_tokens"]
    real_tokens = stats0["prompt_tokens"]

    # padded baselines, in prefill tokens (∝ layer-GEMM FLOPs: every
    # prefill token enters every GEMM regardless of content)
    t_max = max(len(p) for p in prompts)
    rect_tokens = 0          # static waves of max_batch, padded to bucket
    for w0 in range(0, n_req, max_batch):
        wave = prompts[w0:w0 + max_batch]
        rect_tokens += len(wave) * _bucket(max(len(p) for p in wave))
    slot_tokens = sum(_bucket(len(p)) for p in prompts)   # legacy serve

    pad_elim_rect = 1.0 - packed_tokens / rect_tokens
    pad_elim_slot = 1.0 - packed_tokens / slot_tokens

    # parity while we're here: packed == padded scheduler, token for token
    out_padded = eng.serve(prompts, budgets, prefill_mode="padded")
    assert out_packed == out_padded, "packed/padded token mismatch"

    # -- TTFT with/without chunked prefill -------------------------------
    chunk = 16
    ttft_whole = stats0["ttft_s"]
    jitter_whole = stats0["max_prefill_call_tokens"]
    t0 = time.perf_counter()
    out_chunked = eng.serve(prompts, budgets, prefill_mode="packed",
                            prefill_chunk=chunk)
    chunked_wall = time.perf_counter() - t0
    stats1 = dict(eng.serve_stats)
    assert out_chunked == out_packed, "chunked prefill changed tokens"
    jitter_chunked = stats1["max_prefill_call_tokens"]
    assert jitter_chunked <= _bucket(chunk), (
        f"chunked prefill dispatched {jitter_chunked} tokens in one call "
        f"(budget {chunk})")

    res = {
        "workload": {"n_req": n_req, "max_batch": max_batch,
                     "len_max": t_max,
                     "len_median": int(np.median(
                         [len(p) for p in prompts])),
                     "prompt_tokens": real_tokens},
        "prefill_tokens": {
            "packed": int(packed_tokens),
            "padded_rectangle": int(rect_tokens),
            "padded_per_slot_bucket": int(slot_tokens),
        },
        "pad_flop_eliminated_vs_rectangle": round(pad_elim_rect, 4),
        "pad_flop_eliminated_vs_slot_buckets": round(pad_elim_slot, 4),
        "pad_elim_floor": PAD_ELIM_FLOOR,
        "pad_elim_pass": bool(pad_elim_rect >= PAD_ELIM_FLOOR),
        "ttft_whole_prompt": _percentiles(ttft_whole),
        "ttft_chunked": _percentiles(stats1["ttft_s"]),
        "prefill_chunk": chunk,
        "max_prefill_call_tokens": {"whole_prompt": int(jitter_whole),
                                    "chunked": int(jitter_chunked)},
        "serve_wall_s": {"whole_prompt": round(packed_wall, 3),
                         "chunked": round(chunked_wall, 3)},
    }
    assert res["pad_elim_pass"], (
        f"pad-FLOP elimination {pad_elim_rect:.1%} below the "
        f"{PAD_ELIM_FLOOR:.0%} floor on the 4:1 mix")
    print(f"pad-FLOP eliminated: {pad_elim_rect:.1%} vs rectangle, "
          f"{pad_elim_slot:.1%} vs per-slot buckets "
          f"({packed_tokens} packed vs {rect_tokens} rect tokens); "
          f"max single prefill call {jitter_whole} -> {jitter_chunked} "
          f"tokens with chunk={chunk}")
    return res
