"""A/B harness: fused GEMM epilogue vs. separate epilogue passes.

For each benchmark shape the unfused variant runs the kernel to a raw
accumulator and applies scale/bias/activation as separate jitted XLA ops —
one extra read+write of the [M, N] output through HBM. The fused variant
applies the same epilogue inside the kernel's final-K store (DESIGN.md §7).

Reported per shape: best-of-N wall time for both variants, the speedup, and
the bytes-model estimate of the HBM traffic the fusion removes
(2 · M · N · itemsize: one read + one write of the intermediate). On a real
TPU the wall-time gap approaches the bytes model for memory-bound decode
shapes; on the CPU interpret backend the numbers are correctness-grade
only, so `run()` verifies numerical parity strictly (assert) but reports
a fused-slower-than-unfused outcome as a WARNING rather than failing —
interpret-mode timing noise is not a regression signal.

Run:  PYTHONPATH=src python -m benchmarks.fused_epilogue [--fast]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _best_of(fn, n: int = 5) -> float:
    jax.block_until_ready(fn())            # compile + warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# (M, K, N): decode-like row, serving mid-batch, square training tile
SHAPES = [
    (8, 1024, 1024),
    (128, 1024, 4096),
    (512, 512, 512),
]
FAST_SHAPES = [(8, 256, 256), (64, 256, 512)]


def bench_shape(m: int, k: int, n: int, act: str = "silu",
                dtype=jnp.float32, repeats: int = 5) -> dict:
    from repro.kernels.epilogue import apply_act
    from repro.kernels.sta_gemm.ops import sta_gemm

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dtype)
    bias = jax.random.normal(jax.random.fold_in(key, 2), (n,), jnp.float32)
    scale = jnp.linspace(0.5, 2.0, n)

    fused = jax.jit(lambda: sta_gemm(x, w, bias, scale, act=act))

    @jax.jit
    def unfused():
        y = sta_gemm(x, w)                       # raw accumulator to HBM
        y = y.astype(jnp.float32) * scale[None, :] + bias[None, :]
        return apply_act(y, act).astype(x.dtype)  # second pass over [M, N]

    np.testing.assert_allclose(np.asarray(fused(), np.float32),
                               np.asarray(unfused(), np.float32),
                               rtol=5e-3, atol=5e-3)
    t_fused = _best_of(fused, repeats)
    t_unfused = _best_of(unfused, repeats)
    saved = 2 * m * n * jnp.dtype(dtype).itemsize   # read+write removed
    return {"shape": (m, k, n), "act": act,
            "fused_s": t_fused, "unfused_s": t_unfused,
            "speedup": t_unfused / t_fused,
            "hbm_bytes_saved": int(saved)}


def run(fast: bool = False, quiet: bool = False) -> dict:
    shapes = FAST_SHAPES if fast else SHAPES
    rows = [bench_shape(*s) for s in shapes]
    if not quiet:
        print(f"{'M,K,N':>18s} {'act':>5s} {'fused':>10s} {'unfused':>10s} "
              f"{'speedup':>8s} {'HBM saved':>10s}")
        for r in rows:
            m, k, n = r["shape"]
            print(f"{m:>6d},{k:>5d},{n:>5d} {r['act']:>5s} "
                  f"{r['fused_s'] * 1e3:9.2f}ms {r['unfused_s'] * 1e3:9.2f}ms "
                  f"{r['speedup']:7.2f}x {r['hbm_bytes_saved'] / 2 ** 20:8.2f}MB")
        worse = [r for r in rows if r["speedup"] < 0.9]
        if worse:
            print(f"WARNING: fused slower than unfused on {len(worse)} "
                  "shape(s) — interpret-mode noise or a regression")
        else:
            print("fused <= unfused on all benchmark shapes "
                  "(HBM round-trip eliminated)")
    return {"rows": rows}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    run(fast=args.fast)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
