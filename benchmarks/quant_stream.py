"""INT4 groupwise weight streaming A/B — the decode bandwidth floor.

Decode is weight-bandwidth-bound: every generated token re-reads the
whole packed weight tree from HBM, so tokens/sec scales inversely with
weight bytes per token. The w4 format (DESIGN.md §16) halves the DBB
value plane — two INT4 slots per byte plus a per-group ``[K//G, N]``
f32 scale plane — and the w4 kernel routes stream the nibble plane
directly, expanding to int8 only inside VMEM.

Four sections:

  footprint — exact format math (``dbb_footprint_bytes``): HBM bytes
      per decode token for INT8-DBB vs INT4-DBB across model shapes.
  roofline  — the dispatch registry's modeled decode step time for the
      chosen packed route at bits=8 vs bits=4 on bandwidth-bound decode
      shapes. **Asserts** the modeled tokens/sec gain is >= 1.3x — the
      acceptance floor; the format guarantees ~1.5x at B=8/nnz=4/G=128
      so a miss means the cost model or the byte math regressed.
  measured  — small-shape interpret-mode wall clock for the int8 vs w4
      packed GEMM (correctness-grade only on CPU: the interpreter is
      compute-bound, so this is informational, never asserted).
  accuracy  — table1-style DBB CNN training, then fake-quant eval:
      INT8 per-channel vs INT4 groupwise on identical weights/data.
      **Asserts** INT4 costs <= 1% accuracy vs the INT8-DBB baseline.

Run:  PYTHONPATH=src python -m benchmarks.quant_stream [--fast]
"""
from __future__ import annotations

import time
import types

import jax
import jax.numpy as jnp
import numpy as np

# decode-shaped (M = small batch) bandwidth-bound GEMMs: MLP up/down
# projections at 1-2B-param model dims, and the big head GEMV
ROOFLINE_SHAPES = [
    (8, 2048, 8192),
    (8, 8192, 2048),
    (1, 2048, 32768),
]
FOOTPRINT_SHAPES = [(2048, 8192), (8192, 2048), (2048, 32768)]
MEASURED_SHAPES = [(8, 512, 512)]
FAST_MEASURED = [(8, 256, 256)]

SPEEDUP_FLOOR = 1.3         # acceptance floor (ISSUE 10 / DESIGN.md §16)
ACC_FLOOR = 0.01            # <= 1% accuracy cost vs INT8-DBB


def _footprint_rows(block: int = 8, nnz: int = 4, group: int = 128):
    from repro.core.dbb import dbb_footprint_bytes, dense_footprint_bytes
    rows = []
    for k, n in FOOTPRINT_SHAPES:
        dense = dense_footprint_bytes(k, n, itemsize=1)
        b8 = dbb_footprint_bytes(k, n, block, nnz, itemsize=1)
        b4 = dbb_footprint_bytes(k, n, block, nnz, itemsize=1,
                                 bits=4, group=group)
        rows.append({"k": k, "n": n, "dense_int8_bytes": dense,
                     "dbb_int8_bytes": b8, "dbb_int4_bytes": b4,
                     "int4_vs_int8": round(b8 / b4, 4),
                     "int4_vs_dense": round(dense / b4, 4)})
    return rows


def _roofline_rows(group: int = 128):
    from repro.kernels import dispatch
    rows = []
    for m, k, n in ROOFLINE_SHAPES:
        def chosen(**kw):
            ds = dispatch.explain("matmul", m=m, k=k, n=n,
                                  dtype="float32", packed=True,
                                  pallas=True, **kw)
            return next(d for d in ds if d.chosen)
        d8 = chosen()
        d4 = chosen(bits=4, group=group)
        rows.append({
            "shape": (m, k, n),
            "int8_route": d8.name, "int4_route": d4.name,
            "int8_weight_bytes": d8.weight_bytes,
            "int4_weight_bytes": d4.weight_bytes,
            "int8_tok_per_s": m / d8.cost_s,
            "int4_tok_per_s": m / d4.cost_s,
            "speedup": d8.cost_s / d4.cost_s,
        })
    return rows


def _best_of(fn, n: int = 3) -> float:
    jax.block_until_ready(fn())            # compile + warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _measured_rows(fast: bool, block: int = 8, nnz: int = 4):
    from repro.core.dbb import DbbWeight, pack_dbb
    from repro.core.quant import quantize_weight
    from repro.kernels.dbb_gemm.ops import dbb_gemm_packed
    rows = []
    for m, k, n in (FAST_MEASURED if fast else MEASURED_SHAPES):
        group = min(k, 128)
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n),
                              jnp.float32)
        qw = quantize_weight(w)
        p8f = pack_dbb(qw.q.astype(jnp.float32), block, nnz)
        p8 = DbbWeight(values=p8f.values.astype(jnp.int8), indices=None,
                      bitmask=p8f.bitmask, scale=qw.scale, block=block,
                      nnz=nnz, k_dim=k)
        p4 = pack_dbb(w, block, nnz, bits=4, group=group)
        f8 = jax.jit(lambda: dbb_gemm_packed(x, p8))
        f4 = jax.jit(lambda: dbb_gemm_packed(x, p4))
        # parity: both are fake-quantized views of the same w, so they
        # agree to quantization error, not bit-exactly
        y8, y4 = np.asarray(f8()), np.asarray(f4())
        scale = float(np.abs(y8).mean()) or 1.0
        rows.append({"shape": (m, k, n),
                     "int8_s": _best_of(f8), "int4_s": _best_of(f4),
                     "mean_rel_gap": float(np.abs(y8 - y4).mean()) / scale,
                     "note": "interpret-mode wall clock (informational)"})
    return rows


def _fake_quant_tree(params, dbb_cfg, bits: int, group: int):
    """Replace every DBB-eligible leaf with its fake-quantized (pack ->
    unpack) self: INT8 per-out-channel or INT4 groupwise, both through
    the same DBB top-nnz projection the packed formats store."""
    from repro.core.dbb import pack_dbb, unpack_dbb
    from repro.core.quant import quantize_weight
    from repro.core.sparsity import _path_str, dbb_eligible

    def visit(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if not dbb_eligible(_path_str(path), dbb_cfg):
            return leaf
        kd = leaf.shape[-2]
        if kd % dbb_cfg.block != 0:
            return leaf
        g = group if (group > 0 and group % dbb_cfg.block == 0
                      and kd % group == 0) else dbb_cfg.block

        def fq(w2):
            if bits == 4:
                p = pack_dbb(w2.astype(jnp.float32), dbb_cfg.block,
                             dbb_cfg.nnz, bits=4, group=g)
                return unpack_dbb(p).astype(leaf.dtype)
            qw = quantize_weight(w2.astype(jnp.float32))
            p = pack_dbb(qw.q.astype(jnp.float32), dbb_cfg.block,
                         dbb_cfg.nnz)
            return (unpack_dbb(p) * qw.scale[None, :]).astype(leaf.dtype)

        fn = fq
        for _ in range(leaf.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf)

    return jax.tree_util.tree_map_with_path(visit, params)


def _accuracy_rows(steps: int):
    from benchmarks.table1_dbb_accuracy import _accuracy
    from repro.config import DbbConfig, RunConfig, ShapeSpec, TrainConfig
    from repro.configs import get_config
    from repro.launch.train import train_loop

    arch, nnz = "lenet5-dbb", 4
    cfg = get_config(arch, smoke=True)
    cfg = cfg.replace(dbb=DbbConfig(enabled=True, block=8, nnz=nnz,
                                    apply_to=("conv",)))
    rc = RunConfig(model=cfg, train=TrainConfig(
        steps=steps, learning_rate=3e-3, log_every=10 ** 9, seed=0,
        dbb_prune_start=steps // 3, dbb_prune_ramp=steps // 3))
    state, _ = train_loop(rc, ShapeSpec("t", 16, 32, "train"),
                          log=lambda *_: None)

    def acc_of(params):
        return _accuracy(rc, types.SimpleNamespace(params=params))

    acc_f = acc_of(state.params)
    acc_8 = acc_of(_fake_quant_tree(state.params, cfg.dbb, 8, 0))
    acc_4 = acc_of(_fake_quant_tree(state.params, cfg.dbb, 4, 128))
    return {"model": arch, "nnz": nnz, "steps": steps,
            "float_dbb_acc": round(acc_f, 4),
            "int8_dbb_acc": round(acc_8, 4),
            "int4_dbb_acc": round(acc_4, 4),
            "int4_vs_int8_delta": round(acc_8 - acc_4, 4)}


def run(fast: bool = False, quiet: bool = False) -> dict:
    fp = _footprint_rows()
    rf = _roofline_rows()
    ms = _measured_rows(fast)
    acc = _accuracy_rows(steps=30 if fast else 60)

    if not quiet:
        print(f"{'K,N':>14s} {'dense':>10s} {'int8-dbb':>10s} "
              f"{'int4-dbb':>10s} {'vs int8':>8s}")
        for r in fp:
            print(f"{r['k']:>6d},{r['n']:>7d} "
                  f"{r['dense_int8_bytes'] / 2**20:8.2f}MB "
                  f"{r['dbb_int8_bytes'] / 2**20:8.2f}MB "
                  f"{r['dbb_int4_bytes'] / 2**20:8.2f}MB "
                  f"{r['int4_vs_int8']:7.2f}x")
        print(f"\n{'M,K,N':>18s} {'int8 route':>14s} {'int4 route':>14s} "
              f"{'tok/s int8':>11s} {'tok/s int4':>11s} {'speedup':>8s}")
        for r in rf:
            m, k, n = r["shape"]
            print(f"{m:>5d},{k:>5d},{n:>6d} {r['int8_route']:>14s} "
                  f"{r['int4_route']:>14s} {r['int8_tok_per_s']:>11.0f} "
                  f"{r['int4_tok_per_s']:>11.0f} {r['speedup']:7.2f}x")
        for r in ms:
            m, k, n = r["shape"]
            print(f"measured {m},{k},{n}: int8 {r['int8_s']*1e3:.1f}ms "
                  f"int4 {r['int4_s']*1e3:.1f}ms "
                  f"(rel gap {r['mean_rel_gap']:.3f}; {r['note']})")
        print(f"accuracy ({acc['model']}, nnz={acc['nnz']}): "
              f"float-dbb {acc['float_dbb_acc']:.3f} "
              f"int8-dbb {acc['int8_dbb_acc']:.3f} "
              f"int4-dbb {acc['int4_dbb_acc']:.3f} "
              f"(delta {acc['int4_vs_int8_delta']:+.3f})")

    worst = min(r["speedup"] for r in rf)
    assert worst >= SPEEDUP_FLOOR, (
        f"modeled w4 decode speedup {worst:.2f}x under the "
        f"{SPEEDUP_FLOOR}x floor — weight-byte math or the dispatch "
        f"cost model regressed")
    assert acc["int4_vs_int8_delta"] <= ACC_FLOOR + 1e-9, (
        f"INT4 groupwise costs {acc['int4_vs_int8_delta']*100:.2f}% "
        f"accuracy vs INT8-DBB (floor: {ACC_FLOOR*100:.0f}%)")
    if not quiet:
        print(f"modeled decode speedup >= {SPEEDUP_FLOOR}x on all "
              f"bandwidth-bound shapes (worst {worst:.2f}x); INT4 "
              f"accuracy within {ACC_FLOOR*100:.0f}% of INT8-DBB")
    return {"footprint": fp, "roofline": rf, "measured": ms,
            "accuracy": acc, "modeled_speedup_floor": SPEEDUP_FLOOR,
            "worst_modeled_speedup": round(worst, 4)}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    run(fast=args.fast)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
