"""Paper Table II: throughput-normalized area/power efficiency of
SA-NCG / SA / STA / SMT-SA / STA-DBB, from the calibrated analytical model
(core/area_model.py). The RTL flow is replaced by a component-cost model
fitted to the paper's own reported numbers; `--refit` re-derives the
calibration from gate-count priors."""
from __future__ import annotations

import argparse
import json

from repro.core.area_model import (DEFAULT_PARAMS, PAPER_TABLE2,
                                   fit_calibration, table2)


def run(refit: bool = False, quiet: bool = False) -> dict:
    params = DEFAULT_PARAMS
    if refit:
        params, loss = fit_calibration(seed=3)
        if not quiet:
            print(f"refit loss: {loss:.4f}")
    ours = table2(params)
    rows = []
    for name, (pa, pp) in PAPER_TABLE2.items():
        ma, mp = ours[name]
        rows.append({"design": name, "paper_area_eff": pa,
                     "paper_power_eff": pp,
                     "model_area_eff": round(ma, 3),
                     "model_power_eff": round(mp, 3),
                     "area_rel_err": round(abs(ma - pa) / pa, 4),
                     "power_rel_err": round(abs(mp - pp) / pp, 4)})
    if not quiet:
        hdr = (f"{'design':16s} {'paper A/P':>12s} {'model A/P':>14s} "
               f"{'rel.err':>14s}")
        print(hdr)
        for r in rows:
            print(f"{r['design']:16s} "
                  f"{r['paper_area_eff']:5.2f}/{r['paper_power_eff']:4.2f}  "
                  f"  {r['model_area_eff']:6.3f}/{r['model_power_eff']:5.3f} "
                  f"  {r['area_rel_err']:5.1%}/{r['power_rel_err']:5.1%}")
    mean_err = sum(r["area_rel_err"] + r["power_rel_err"]
                   for r in rows) / (2 * len(rows))
    return {"table": rows, "mean_rel_err": mean_err}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--refit", action="store_true")
    args = ap.parse_args(argv)
    out = run(refit=args.refit)
    print(f"mean relative error vs paper Table II: {out['mean_rel_err']:.2%}")
    return out


if __name__ == "__main__":
    main()
