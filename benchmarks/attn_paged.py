"""Attention + paged-KV A/B (DESIGN.md §10): flash vs chunked wall-clock
and paged vs contiguous serving occupancy at a fixed HBM budget.

1. **Flash vs chunked prefill**: the fused Pallas flash kernel against the
   blocked XLA running-softmax path on the same q/k/v. On this CPU
   container the Pallas kernel runs in *interpret mode* (per-block
   emulation), so the wall-clock column is a correctness-tracked artifact,
   not a perf claim — the structural win (no ``[B, H, T, S]`` score
   tensor, reported as the peak-intermediate ratio from the traced jaxprs)
   is backend-independent and is what transfers to TPU.

2. **Paged vs contiguous occupancy**: serve a mixed short/long workload
   twice at the SAME KV HBM budget — once through the contiguous cache
   (every slot reserves ``smax`` slots, so the budget caps the slot
   count) and once through the paged pool (admission by pages actually
   used). Both engines run the same flash decode kernel (identity vs real
   block table), so tokens are bit-identical; the paged engine must reach
   ≥ ``OCCUPANCY_FLOOR``× the contiguous max-concurrent-rows.

Emitted as the ``attn_paged`` section of ``BENCH_attn.json`` by
`benchmarks.run` (CI smoke-runs it and uploads the file).
"""
from __future__ import annotations

import math
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

OCCUPANCY_FLOOR = 1.5   # acceptance: paged ≥ 1.5× contiguous rows


def _best_of(fn, n: int = 3) -> float:
    jax.block_until_ready(fn())           # warmup / compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _peak_intermediate(fn, *args) -> int:
    """Largest intermediate aval (elements) in the traced computation —
    the shared walker from the static verifier."""
    from repro.analysis.materialize import max_intermediate_elems
    return max_intermediate_elems(fn, *args)


def _flash_vs_chunked(fast: bool) -> Dict:
    from repro.configs import get_config
    from repro.kernels.attn.ops import flash_attention
    from repro.models.attention import _chunked_causal_attention

    cfg = get_config("olmo-1b", smoke=True).replace(attn_chunk=64)
    b, t, hq, hkv, d = (1, 256, 4, 4, 32) if fast else (2, 1024, 8, 4, 64)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, t, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, hkv, d))

    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, block_q=64,
                                                    block_kv=64))
    chunked = jax.jit(lambda q, k, v: _chunked_causal_attention(
        q, k, v, cfg, cfg.attn_chunk))
    o_f = flash(q, k, v)
    o_c = chunked(q, k, v)
    err = float(jnp.abs(o_f.astype(jnp.float32)
                        - o_c.astype(jnp.float32)).max())
    assert err < 1e-3, f"flash/chunked diverged: {err}"

    t_f = _best_of(lambda: flash(q, k, v))
    t_c = _best_of(lambda: chunked(q, k, v))
    peak_f = _peak_intermediate(
        lambda q, k, v: flash_attention(q, k, v, block_q=64, block_kv=64),
        q, k, v)
    peak_naive = b * hq * t * t           # what the oracle materializes
    row = {
        "shape_bthd": [b, t, hq, d],
        "flash_ms": round(t_f * 1e3, 2),
        "chunked_xla_ms": round(t_c * 1e3, 2),
        "flash_peak_intermediate_elems": int(peak_f),
        "naive_score_tensor_elems": int(peak_naive),
        "peak_intermediate_ratio": round(peak_naive / peak_f, 2),
        "note": "flash runs in Pallas interpret mode on CPU — wall-clock "
                "is tracked for trend, the peak-intermediate ratio is the "
                "structural claim",
    }
    print(f"  flash {row['flash_ms']} ms vs chunked-XLA "
          f"{row['chunked_xla_ms']} ms (interpret-mode CPU); "
          f"peak intermediate {peak_f} vs naive {peak_naive} "
          f"({row['peak_intermediate_ratio']}x smaller)")
    assert peak_f < peak_naive, "flash materialized the score tensor"
    return row


def _paged_occupancy(fast: bool) -> Dict:
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    from repro.serve.kv_cache import pages_needed

    page = 8
    cfg = get_config("olmo-1b", smoke=True).replace(
        remat="none", attn_impl="flash", kv_page_size=page)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_req = 8 if fast else 16
    prompts = [list(rng.integers(2, 500, size=6)) for _ in range(n_req)]
    # one long request per arrival wave pins smax; the rest are short
    budgets = [24 if i % 4 == 0 else 4 for i in range(n_req)]

    # serve() buckets: prompts → 8 slots, smax → bucket(8 + 24) = 32
    smax = 32
    n_log = smax // page
    # fixed HBM budget: the KV bytes of `slots_c` contiguous slots
    slots_c = 3
    budget_pages = slots_c * n_log
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    page_bytes = (2 * cfg.num_layers * page * hkv * hd
                  * jnp.dtype(cfg.dtype).itemsize)

    eng_c = ServeEngine(cfg, params, max_batch=slots_c, fetch_chunk=4,
                        paged=False)
    out_c = eng_c.serve(prompts, max_new_tokens=budgets)
    eng_p = ServeEngine(cfg, params, max_batch=n_req, fetch_chunk=4,
                        kv_pool_pages=budget_pages + 1)   # +1: dummy page
    out_p = eng_p.serve(prompts, max_new_tokens=budgets)
    assert out_p == out_c, "paged serving must be bit-identical"

    peak_paged = eng_p.serve_stats["peak_active"]
    need_short = pages_needed(8, 4, page)
    row = {
        "hbm_budget_pages": budget_pages,
        "hbm_budget_mb": round(budget_pages * page_bytes / 1e6, 3),
        "page_slots": page,
        "smax_slots": smax,
        "contiguous_max_rows": slots_c,
        "paged_peak_rows": int(peak_paged),
        "paged_rows_analytic_short": budget_pages // need_short,
        "occupancy_ratio": round(peak_paged / slots_c, 2),
        "deferred_admissions": eng_p.serve_stats["deferred_admissions"],
        "n_requests": n_req,
        "bit_identical_tokens": True,
    }
    print(f"  fixed budget {budget_pages} pages: contiguous {slots_c} rows "
          f"vs paged peak {peak_paged} rows "
          f"({row['occupancy_ratio']}x, floor {OCCUPANCY_FLOOR}x)")
    assert peak_paged >= OCCUPANCY_FLOOR * slots_c, (
        f"paged occupancy {peak_paged}/{slots_c} below "
        f"{OCCUPANCY_FLOOR}x floor")
    return row


def run(fast: bool = False) -> Dict:
    return {
        "flash_prefill": _flash_vs_chunked(fast),
        "paged_occupancy": _paged_occupancy(fast),
    }


if __name__ == "__main__":
    run()
