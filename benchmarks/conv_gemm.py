"""A/B harness: implicit-GEMM conv (fused im2col in-kernel, DESIGN.md §8)
vs the materialized-im2col lowering it replaces.

For each conv shape the *materialized* variant runs exactly the pre-PR-2
`models/cnn.py` path — `im2col` writes the [B·Ho·Wo, kh·kw·C] patch
matrix, then `sta_gemm` consumes it with the fused epilogue — and the
*implicit* variant runs `conv_gemm`, whose K loop gathers the patch tiles
from the NHWC block in VMEM, so the patch matrix never exists in HBM.

Reported per shape: best-of-N wall time for both variants, the speedup,
and the peak-activation-bytes model: the materialized path's live set is
input + patch matrix + output, the implicit path's is padded input +
output — the difference is the kh·kw× im2col blowup the paper's mobile
setting cannot afford. Numerical parity is asserted strictly; on the CPU
interpret backend wall times are correctness-grade, so a slower-implicit
outcome prints a WARNING rather than failing.

Run:  PYTHONPATH=src python -m benchmarks.conv_gemm [--fast]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _best_of(fn, n: int = 5) -> float:
    jax.block_until_ready(fn())            # compile + warmup
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# (name, B, H, W, Cin, Cout, k, stride) — mobile-CNN inference shapes
SHAPES = [
    ("cifar_conv2", 8, 32, 32, 64, 64, 3, 1),
    ("blk2_conv2",  2, 28, 28, 128, 128, 3, 1),
    ("stride2",     4, 32, 32, 32, 64, 3, 2),
]
FAST_SHAPES = [
    ("small_3x3",   2, 16, 16, 32, 32, 3, 1),
    ("small_s2",    2, 16, 16, 16, 32, 3, 2),
]


def bench_shape(name: str, b: int, h: int, w: int, c: int, n: int, k: int,
                stride: int, repeats: int = 5) -> dict:
    from repro.kernels.conv_gemm.ops import conv_gemm, out_spatial
    from repro.kernels.conv_gemm.ref import im2col
    from repro.kernels.sta_gemm.ops import sta_gemm

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, h, w, c), jnp.float32)
    wm = jax.random.normal(jax.random.fold_in(key, 1), (k * k * c, n),
                           jnp.float32) * 0.1
    bias = jax.random.normal(jax.random.fold_in(key, 2), (n,), jnp.float32)

    implicit = jax.jit(
        lambda x: conv_gemm(x, wm, bias, kh=k, kw=k, stride=stride,
                            act="relu"))

    @jax.jit
    def materialized(x):
        cols = im2col(x, k, k, stride)          # the HBM patch matrix
        bb, ho, wo, kd = cols.shape
        y = sta_gemm(cols.reshape(-1, kd), wm, bias, act="relu")
        return y.reshape(bb, ho, wo, n)

    y_imp = implicit(x)
    y_mat = materialized(x)
    np.testing.assert_allclose(np.asarray(y_imp), np.asarray(y_mat),
                               rtol=1e-4, atol=1e-4)

    t_imp = _best_of(lambda: implicit(x), repeats)
    t_mat = _best_of(lambda: materialized(x), repeats)

    ho, _, _ = out_spatial(h, k, stride, "SAME")
    wo, _, _ = out_spatial(w, k, stride, "SAME")
    itemsize = 4
    in_b = b * h * w * c * itemsize
    pad_in_b = b * ((ho - 1) * stride + k) * ((wo - 1) * stride + k) \
        * c * itemsize
    cols_b = b * ho * wo * k * k * c * itemsize
    out_b = b * ho * wo * n * itemsize
    return {
        "name": name,
        "shape": {"B": b, "H": h, "W": w, "Cin": c, "Cout": n, "k": k,
                  "stride": stride},
        "implicit_s": t_imp,
        "materialized_s": t_mat,
        "speedup": t_mat / t_imp,
        "peak_act_bytes_implicit": pad_in_b + out_b,
        "peak_act_bytes_materialized": in_b + cols_b + out_b,
        "act_saving": 1 - (pad_in_b + out_b) / (in_b + cols_b + out_b),
        "im2col_bytes_avoided": cols_b,
    }


def run(fast: bool = False, quiet: bool = False) -> dict:
    shapes = FAST_SHAPES if fast else SHAPES
    rows = [bench_shape(*s) for s in shapes]
    if not quiet:
        print(f"{'layer':>12s} {'implicit':>10s} {'im2col+GEMM':>12s} "
              f"{'speedup':>8s} {'peak act (imp/mat)':>22s} {'saving':>7s}")
        for r in rows:
            print(f"{r['name']:>12s} {r['implicit_s'] * 1e3:9.2f}ms "
                  f"{r['materialized_s'] * 1e3:11.2f}ms "
                  f"{r['speedup']:7.2f}x "
                  f"{r['peak_act_bytes_implicit'] / 2**20:9.2f}MB/"
                  f"{r['peak_act_bytes_materialized'] / 2**20:6.2f}MB "
                  f"{r['act_saving']:6.1%}")
        worse = [r for r in rows if r["speedup"] < 1.0]
        if worse:
            print(f"WARNING: implicit slower than materialized on "
                  f"{len(worse)} shape(s) — interpret-mode noise or a "
                  "regression")
        else:
            print("implicit-GEMM beats the materialized-im2col path on all "
                  "benchmark shapes (patch matrix never hits HBM)")
    return {"rows": rows}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    run(fast=args.fast)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
