"""Beyond-paper: the 40-cell roofline table from the multi-pod dry-run
artifacts (launch/dryrun.py writes artifacts/dryrun/*.json; EXPERIMENTS.md
§Roofline is generated from this table)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "pod", packed: bool | None = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh:
            continue
        if packed is not None and r.get("packed", False) != packed:
            continue
        rows.append(r)
    return rows


def fmt_row(r) -> str:
    cell = f"{r['arch']}×{r['shape']}"
    if r["status"] == "skipped":
        return f"{cell:42s} SKIP ({r['reason'][:48]}...)"
    if r["status"] != "ok":
        return f"{cell:42s} ERROR"
    t = r["roofline"]
    mem = r.get("memory", {})
    fits = mem.get("total_per_device", 0) <= 16e9
    return (f"{cell:42s} C {t['compute_s']:9.3g}s  M {t['memory_s']:9.3g}s "
            f" X {t['collective_s']:9.3g}s  -> {t['bottleneck']:10s} "
            f"frac {t['roofline_fraction']:6.3f} "
            f"{'fits' if fits else 'OVER'}")


def run(quiet: bool = False, mesh: str = "pod") -> dict:
    rows = load_cells(mesh)
    if not rows:
        print(f"no dry-run artifacts under {ART}; run "
              "`python -m repro.launch.dryrun` first")
        return {"rows": []}
    ok = [r for r in rows if r["status"] == "ok"]
    if not quiet:
        print(f"== roofline table ({mesh} mesh, {len(ok)} compiled cells, "
              f"{len(rows) - len(ok)} skipped/failed) ==")
        for r in rows:
            print(fmt_row(r))
        if ok:
            worst = min(
                (r for r in ok if r["shape"] == "train_4k"),
                key=lambda r: r["roofline"]["roofline_fraction"],
                default=None)
            if worst:
                print(f"\nworst train roofline fraction: "
                      f"{worst['arch']} "
                      f"({worst['roofline']['roofline_fraction']:.3f})")
    return {"rows": rows}


def main(argv=None):
    return run()


if __name__ == "__main__":
    main()
