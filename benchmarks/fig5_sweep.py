"""Paper Fig. 5: area/power at iso-throughput across the A×B×C tensor-PE
design space (model sweep), plus the TPU analogue — a Pallas block-shape
sweep over the STA GEMM kernel reporting arithmetic intensity and VMEM
footprint per (bm, bk, bn) (the quantities that decide the MXU sweet spot,
from the same geometry module the kernels tile with)."""
from __future__ import annotations

import argparse
import itertools
import json

from repro.core.area_model import fig5_sweep
from repro.core.sta import VMEM_BYTES, mxu_utilization


def pallas_block_sweep(m=4096, k=4096, n=4096, itemsize=1):
    """For each candidate block: VMEM working set, arithmetic intensity
    (flops per HBM byte), and MXU alignment utilization."""
    rows = []
    for bm, bk, bn in itertools.product((128, 256, 512), (128, 256, 512),
                                        (128, 256, 512)):
        ws = (bm * bk + bk * bn) * itemsize + bm * bn * 4
        if ws > VMEM_BYTES // 2:
            continue
        # per output tile: bm*bn*K flops; HBM traffic = K*(bm+bn) operands
        flops = 2 * bm * bn * k
        traffic = k * (bm + bn) * itemsize + bm * bn * 4
        rows.append({"bm": bm, "bk": bk, "bn": bn,
                     "vmem_bytes": ws,
                     "arith_intensity": round(flops / traffic, 1),
                     "mxu_util": round(mxu_utilization(bm, bk, bn), 3)})
    rows.sort(key=lambda r: -r["arith_intensity"])
    return rows


def run(quiet: bool = False) -> dict:
    model_rows = fig5_sweep()
    best_sta = min(model_rows, key=lambda r: r["sta_area"])
    best_dbb = min((r for r in model_rows if "dbb_area" in r),
                   key=lambda r: r["dbb_area"])
    pl = pallas_block_sweep()
    if not quiet:
        print(f"design points: {len(model_rows)}")
        print(f"best STA area point: {best_sta['a']}x{best_sta['b']}x"
              f"{best_sta['c']} -> {best_sta['sta_area']:.3f}x SA area "
              f"(paper sweet spot 4x8x4)")
        print(f"best STA-DBB area point: {best_dbb['a']}x{best_dbb['b']}x"
              f"{best_dbb['c']} -> {best_dbb['dbb_area']:.3f}x SA area")
        print("top Pallas blocks by arithmetic intensity:")
        for r in pl[:5]:
            print("  ", json.dumps(r))
    return {"model_sweep": model_rows, "best_sta": best_sta,
            "best_dbb": best_dbb, "pallas_sweep": pl[:10]}


def main(argv=None):
    return run()


if __name__ == "__main__":
    main()
